//! PocketNN-style baseline [20]: native integer-only MLP trained with
//! Direct Feedback Alignment (DFA) and pocket (piecewise-linear integer)
//! activations.
//!
//! This is the state-of-the-art the paper compares against in Table 1.
//! Faithful to PocketNN's ingredients — integer-only arithmetic, DFA
//! (fixed random feedback matrices carry the output error directly to each
//! hidden layer; no transpose of forward weights, no inter-layer gradient
//! chain), pocket-tanh activation — while sharing this repo's numeric
//! plumbing (NITRO scaling keeps pre-activations in int8 range, the same
//! one-hot-32 targets and batch-summed updates), so differences in Table 1
//! reflect the *learning algorithm*, not incidental format choices.

use crate::data::{Batcher, Dataset};
use crate::nn::init::init_weights;
use crate::optim::integer_sgd;
use crate::tensor::{
    matmul_at_b_i64, matmul_i64, nitro_scale, one_hot32,
    rss_loss_grad, scale_factor_linear, ITensor, Tensor,
};
use crate::util::rng::Pcg32;

/// Pocket-tanh: odd, saturating, piecewise-linear integer approximation of
/// 127·tanh(x/64) with slopes 1, 1/2, 1/4, 0 — divisions are exact shifts.
pub fn pocket_tanh(x: i32) -> i32 {
    let neg = x < 0;
    let a = x.unsigned_abs() as i32;
    let y = if a <= 32 {
        a
    } else if a <= 96 {
        32 + (a - 32) / 2 // slope 1/2 -> up to 64
    } else if a <= 224 {
        64 + (a - 96) / 4 // slope 1/4 -> up to 96
    } else {
        96
    }
    .min(127);
    if neg { -y } else { y }
}

/// Derivative gate of pocket_tanh as an inverse slope (divide the incoming
/// delta by it); 0 marks the saturated region (kills the delta).
fn pocket_tanh_slope_inv(x: i32) -> i64 {
    let a = x.unsigned_abs();
    if a <= 32 {
        1
    } else if a <= 96 {
        2
    } else if a <= 224 {
        4
    } else {
        0
    }
}

pub struct PocketNet {
    pub dims: Vec<usize>, // input, hidden..., classes
    pub weights: Vec<ITensor>,
    /// DFA feedback matrices B_l: (G, hidden_l), fixed random.
    pub feedback: Vec<ITensor>,
    pub num_classes: usize,
}

impl PocketNet {
    pub fn new(dims: &[usize], seed: u64) -> Self {
        assert!(dims.len() >= 2);
        let mut rng = Pcg32::new(seed);
        let num_classes = *dims.last().unwrap();
        let mut weights = Vec::new();
        for w in dims.windows(2) {
            weights.push(init_weights(&mut rng, &[w[0], w[1]], w[0]));
        }
        // feedback matrices for hidden layers only, entries in +-16 (small
        // fixed integers; DFA only needs random sign structure)
        let mut feedback = Vec::new();
        for &h in &dims[1..dims.len() - 1] {
            let n = num_classes * h;
            feedback.push(Tensor::from_vec(
                &[num_classes, h],
                (0..n).map(|_| rng.range_i32(-16, 16)).collect(),
            ));
        }
        PocketNet { dims: dims.to_vec(), weights, feedback, num_classes }
    }

    /// Forward; caches pre-activations (scaled) per hidden layer.
    fn forward(&self, x: &ITensor) -> (Vec<ITensor>, Vec<ITensor>, ITensor) {
        let mut acts = vec![x.clone()];
        let mut zss = Vec::new();
        let last = self.weights.len() - 1;
        for (li, w) in self.weights.iter().enumerate() {
            let a = acts.last().unwrap();
            let z = matmul_i64(a, w);
            let zs = nitro_scale(&z, scale_factor_linear(w.shape[0]));
            if li == last {
                return (acts, zss, zs); // linear output layer
            }
            let act = ITensor {
                shape: zs.shape.clone(),
                data: zs.data.iter().map(|&v| pocket_tanh(v)).collect(),
            };
            zss.push(zs);
            acts.push(act);
        }
        unreachable!()
    }

    pub fn infer(&self, x: &ITensor) -> ITensor {
        self.forward(x).2
    }

    /// One DFA training step; returns the RSS loss.
    pub fn train_batch(&mut self, x: &ITensor, labels: &[usize],
                       gamma_inv: i64) -> i64 {
        let y32 = one_hot32(labels, self.num_classes);
        let (acts, zss, yhat) = self.forward(x);
        let (loss, e) = rss_loss_grad(&yhat, &y32); // (B, G)
        let last = self.weights.len() - 1;
        // output layer: standard delta rule
        let gw = matmul_at_b_i64(&acts[last], &e);
        integer_sgd(&mut self.weights[last], &gw, gamma_inv, 0);
        // hidden layers: delta_l = (e · B_l) gated by pocket-tanh slope —
        // the error is teleported by the fixed random feedback, never
        // back-propagated through the forward weights (DFA).
        for li in 0..last {
            let delta = matmul_i64(&e, &self.feedback[li]); // (B, h) i64
            let zs = &zss[li];
            let gated = ITensor {
                shape: zs.shape.clone(),
                data: zs
                    .data
                    .iter()
                    .zip(&delta.data)
                    .map(|(&z, &d)| {
                        let s = pocket_tanh_slope_inv(z);
                        if s == 0 { 0 } else { d.div_euclid(s) as i32 }
                    })
                    .collect(),
            };
            let gw = matmul_at_b_i64(&acts[li], &gated);
            integer_sgd(&mut self.weights[li], &gw, gamma_inv, 0);
        }
        loss
    }

    pub fn accuracy(&self, ds: &Dataset, batch: usize) -> f64 {
        let mut correct = 0usize;
        for (x, labels) in Batcher::sequential(ds, batch, true) {
            let yhat = self.infer(&x);
            correct += crate::nn::block::count_correct(&yhat, &labels);
        }
        correct as f64 / ds.len().max(1) as f64
    }
}

/// Train a PocketNN-style MLP; the Table 1 baseline driver.
pub fn train(dims: &[usize], train: &Dataset, test: &Dataset, epochs: usize,
             batch: usize, gamma_inv: i64, seed: u64) -> (PocketNet, f64) {
    let mut net = PocketNet::new(dims, seed);
    let mut rng = Pcg32::with_stream(seed, 0xdfa);
    for _ in 0..epochs {
        for (x, labels) in Batcher::new(train, batch, true, &mut rng) {
            net.train_batch(&x, &labels, gamma_inv);
        }
    }
    let acc = net.accuracy(test, batch);
    (net, acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn pocket_tanh_shape() {
        assert_eq!(pocket_tanh(0), 0);
        assert_eq!(pocket_tanh(32), 32);
        assert_eq!(pocket_tanh(-32), -32);
        assert_eq!(pocket_tanh(96), 64);
        assert_eq!(pocket_tanh(1000), 96);
        assert_eq!(pocket_tanh(-1000), -96);
        // odd + monotone
        for x in -300..300 {
            assert_eq!(pocket_tanh(-x), -pocket_tanh(x));
            assert!(pocket_tanh(x + 1) >= pocket_tanh(x));
        }
    }

    #[test]
    fn dfa_learns_tiny() {
        let mut ds = synthetic::by_name("tiny", 400, 5).unwrap();
        ds.mad_normalize();
        let (tr, te) = ds.split_test(80);
        let (_, acc) = train(&[64, 48, 10], &tr, &te, 8, 32, 512, 1);
        assert!(acc > 0.35, "pocketnn acc {acc} (chance = 0.1)");
    }

    #[test]
    fn feedback_matrices_fixed_during_training() {
        let mut ds = synthetic::by_name("tiny", 64, 6).unwrap();
        ds.mad_normalize();
        let mut net = PocketNet::new(&[64, 32, 10], 3);
        let fb0 = net.feedback[0].clone();
        let (x, labels) = ds.gather(&(0..32).collect::<Vec<_>>(), true);
        net.train_batch(&x, &labels, 512);
        assert_eq!(net.feedback[0], fb0);
    }

    #[test]
    fn weights_update_in_all_layers() {
        let mut ds = synthetic::by_name("tiny", 64, 7).unwrap();
        ds.mad_normalize();
        let mut net = PocketNet::new(&[64, 32, 10], 3);
        let before: Vec<ITensor> = net.weights.clone();
        let (x, labels) = ds.gather(&(0..32).collect::<Vec<_>>(), true);
        for _ in 0..5 {
            net.train_batch(&x, &labels, 64);
        }
        for (b, a) in before.iter().zip(&net.weights) {
            assert_ne!(b, a, "a layer never updated");
        }
    }
}
