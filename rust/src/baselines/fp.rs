//! Floating-point baselines on the shared topologies.
//!
//! `FpNet` instantiates the same `NetworkSpec` the integer path uses, in
//! f32, with LeakyReLU(0.1) (the float analogue of NITRO-ReLU) and no
//! biases (matching the integer architecture, App. B.1).
//!
//! Two trainers:
//! * [`train_bp`] — global backpropagation, Adam + softmax CrossEntropy.
//!   This is the paper's "FP BP" column: the strongest reference.
//! * [`train_les`] — Local Error Signals [16]: per-block local linear
//!   heads with local CE losses; gradients do not cross block boundaries.
//!   This is the paper's "FP LES" column and the direct float twin of the
//!   NITRO-D learning algorithm.

use crate::data::{Batcher, Dataset};
use crate::nn::spec::{BlockSpec, NetworkSpec};
use super::optim_fp::Adam;
use crate::tensor::ops_f32 as f;
use crate::tensor::{FTensor, Tensor};
use crate::util::rng::Pcg32;

/// One float layer mirroring a local-loss block's forward layers.
pub enum FLayer {
    Conv {
        w: FTensor,
        padding: usize,
        pool: bool,
        /// local LES head (F, G); unused under BP
        head: FTensor,
        /// adaptive-pool geometry (s, k) mirroring the integer block
        lr_pool: (usize, usize),
        out_ch: usize,
    },
    Linear {
        w: FTensor,
        head: FTensor,
    },
}

pub struct FpNet {
    pub spec: NetworkSpec,
    pub layers: Vec<FLayer>,
    pub head: FTensor,
}

fn he_uniform(rng: &mut Pcg32, shape: &[usize], fan_in: usize) -> FTensor {
    let b = (6.0f32 / fan_in as f32).sqrt();
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.range_f32(-b, b)).collect())
}

impl FpNet {
    pub fn new(spec: NetworkSpec, seed: u64) -> Self {
        let mut rng = Pcg32::new(seed);
        let mut layers = Vec::new();
        for blk in &spec.blocks {
            match blk {
                BlockSpec::Conv(c) => layers.push(FLayer::Conv {
                    w: he_uniform(&mut rng, &c.wf_shape(), c.fan_in()),
                    padding: c.padding,
                    pool: c.pool,
                    head: he_uniform(&mut rng, &c.wl_shape(), c.lr_features()),
                    lr_pool: c.lr_pool(),
                    out_ch: c.out_channels,
                }),
                BlockSpec::Linear(l) => layers.push(FLayer::Linear {
                    w: he_uniform(&mut rng, &l.wf_shape(), l.fan_in()),
                    head: he_uniform(&mut rng, &l.wl_shape(), l.out_features),
                }),
            }
        }
        let head = he_uniform(
            &mut rng,
            &[spec.head.in_features, spec.head.num_classes],
            spec.head.fan_in(),
        );
        FpNet { spec, layers, head }
    }

    fn flatten_if(a: FTensor, next_linear: bool) -> FTensor {
        if next_linear && a.shape.len() > 2 {
            let (b, f_) = a.batch_feat();
            a.reshaped(&[b, f_])
        } else {
            a
        }
    }

    /// Forward producing logits; optionally records the per-layer caches.
    pub fn forward(&self, x: &FTensor, caches: Option<&mut Vec<FCache>>)
                   -> FTensor {
        let mut a = x.clone();
        let mut caches = caches;
        for layer in &self.layers {
            let next_linear = matches!(layer, FLayer::Linear { .. });
            a = Self::flatten_if(a, next_linear);
            let (out, cache) = layer_forward(layer, &a);
            if let Some(c) = caches.as_deref_mut() {
                c.push(cache);
            }
            a = out;
        }
        let (b, f_) = a.batch_feat();
        let a = a.reshaped(&[b, f_]);
        let logits = f::matmul(&a, &self.head);
        if let Some(c) = caches.as_deref_mut() {
            c.push(FCache { a_in: a, z: None, pool_arg: None, act_shape: vec![] });
        }
        logits
    }

    pub fn accuracy(&self, ds: &Dataset, batch: usize) -> f64 {
        let flatten = self.spec.input_shape.len() == 1;
        let mut correct = 0usize;
        for (x, labels) in Batcher::sequential(ds, batch, flatten) {
            let xf = to_f32(&x);
            let logits = self.forward(&xf, None);
            correct += argmax_correct(&logits, &labels);
        }
        correct as f64 / ds.len().max(1) as f64
    }
}

/// Forward cache of one layer.
pub struct FCache {
    pub a_in: FTensor,
    /// pre-activation (before LeakyReLU)
    pub z: Option<FTensor>,
    pub pool_arg: Option<(Vec<u32>, Vec<usize>)>, // (argmax, pre-pool shape)
    pub act_shape: Vec<usize>,
}

fn layer_forward(layer: &FLayer, a: &FTensor) -> (FTensor, FCache) {
    match layer {
        FLayer::Conv { w, padding, pool, .. } => {
            let z = f::conv2d(a, w, *padding);
            let act = f::leaky_relu(&z, 0.1);
            if *pool {
                let shape = act.shape.clone();
                let (p, arg) = f::maxpool2d(&act, 2, 2);
                (
                    p,
                    FCache {
                        a_in: a.clone(),
                        z: Some(z),
                        pool_arg: Some((arg, shape.clone())),
                        act_shape: shape,
                    },
                )
            } else {
                let shape = act.shape.clone();
                (
                    act,
                    FCache {
                        a_in: a.clone(),
                        z: Some(z),
                        pool_arg: None,
                        act_shape: shape,
                    },
                )
            }
        }
        FLayer::Linear { w, .. } => {
            let z = f::matmul(a, w);
            let act = f::leaky_relu(&z, 0.1);
            let shape = act.shape.clone();
            (
                act,
                FCache { a_in: a.clone(), z: Some(z), pool_arg: None,
                         act_shape: shape },
            )
        }
    }
}

fn to_f32(x: &crate::tensor::ITensor) -> FTensor {
    // integer-preprocessed pixels (~sigma 64) scaled to ~unit variance
    Tensor {
        shape: x.shape.clone(),
        data: x.data.iter().map(|&v| v as f32 / 64.0).collect(),
    }
}

fn argmax_correct(logits: &FTensor, labels: &[usize]) -> usize {
    let (b, g) = (logits.shape[0], logits.shape[1]);
    let mut c = 0;
    for i in 0..b {
        let row = &logits.data[i * g..(i + 1) * g];
        let mut best = 0usize;
        for j in 1..g {
            if row[j] > row[best] {
                best = j;
            }
        }
        if best == labels[i] {
            c += 1;
        }
    }
    c
}

/// Result shared by both float trainers.
pub struct FpResult {
    pub test_acc: f64,
    pub train_acc: f64,
    pub losses: Vec<f32>,
}

/// FP BP: full backprop with Adam + CrossEntropy.
pub fn train_bp(net: &mut FpNet, train: &Dataset, test: &Dataset,
                epochs: usize, batch: usize, lr: f32, seed: u64) -> FpResult {
    let flatten = net.spec.input_shape.len() == 1;
    let mut rng = Pcg32::with_stream(seed, 0xf9);
    let mut opt = Adam::new(lr);
    let mut losses = Vec::new();
    let mut train_correct = 0usize;
    let mut train_seen = 0usize;
    for _ in 0..epochs {
        train_correct = 0;
        train_seen = 0;
        for (xi, labels) in Batcher::new(train, batch, flatten, &mut rng) {
            let x = to_f32(&xi);
            let mut caches = Vec::new();
            let logits = net.forward(&x, Some(&mut caches));
            train_correct += argmax_correct(&logits, &labels);
            train_seen += labels.len();
            let (loss, dlogits) = f::softmax_ce(&logits, &labels);
            losses.push(loss);
            opt.tick();
            // head
            let head_cache = caches.pop().unwrap();
            let ghead = f::matmul_at_b(&head_cache.a_in, &dlogits);
            let mut d = f::matmul_a_bt(&dlogits, &net.head);
            opt.update(net.layers.len(), &mut net.head, &ghead);
            // layers in reverse
            for (li, layer) in net.layers.iter_mut().enumerate().rev() {
                let cache = &caches[li];
                // reshape d to this layer's output shape
                match layer {
                    FLayer::Conv { w, padding, pool, .. } => {
                        let mut dc = if *pool {
                            let (arg, pre) = cache.pool_arg.as_ref().unwrap();
                            let (ph, pw) = (pre[2] / 2, pre[3] / 2);
                            let dg = d.reshaped(&[pre[0], pre[1], ph, pw]);
                            f::maxpool2d_bwd(&dg, arg, pre, 2, 2)
                        } else {
                            d.reshaped(&[
                                cache.act_shape[0],
                                cache.act_shape[1],
                                cache.act_shape[2],
                                cache.act_shape[3],
                            ])
                        };
                        dc = f::leaky_relu_bwd(cache.z.as_ref().unwrap(), &dc, 0.1);
                        let gw = f::conv2d_weight_grad(&cache.a_in, &dc, 3,
                                                       *padding);
                        d = f::conv2d_input_grad(&dc, w, *padding);
                        let (b_, f_) = d.batch_feat();
                        d = d.reshaped(&[b_, f_]);
                        opt.update(li, w, &gw);
                    }
                    FLayer::Linear { w, .. } => {
                        let dz = f::leaky_relu_bwd(
                            cache.z.as_ref().unwrap(),
                            &d.reshaped(&[
                                cache.act_shape[0],
                                cache.act_shape[1],
                            ]),
                            0.1,
                        );
                        let gw = f::matmul_at_b(&cache.a_in, &dz);
                        d = f::matmul_a_bt(&dz, w);
                        opt.update(li, w, &gw);
                    }
                }
            }
        }
    }
    FpResult {
        test_acc: net.accuracy(test, batch),
        train_acc: train_correct as f64 / train_seen.max(1) as f64,
        losses,
    }
}

/// FP LES [16]: local CE heads per block; no gradient crosses blocks.
pub fn train_les(net: &mut FpNet, train: &Dataset, test: &Dataset,
                 epochs: usize, batch: usize, lr: f32, seed: u64) -> FpResult {
    let flatten = net.spec.input_shape.len() == 1;
    let mut rng = Pcg32::with_stream(seed, 0x1e5);
    let mut opt = Adam::new(lr);
    let mut losses = Vec::new();
    let mut train_correct = 0usize;
    let mut train_seen = 0usize;
    let nl = net.layers.len();
    for _ in 0..epochs {
        train_correct = 0;
        train_seen = 0;
        for (xi, labels) in Batcher::new(train, batch, flatten, &mut rng) {
            let x = to_f32(&xi);
            opt.tick();
            let mut a = x;
            let mut batch_loss = 0f32;
            for (li, layer) in net.layers.iter_mut().enumerate() {
                let next_linear = matches!(layer, FLayer::Linear { .. });
                a = FpNet::flatten_if(a, next_linear);
                let (out, cache) = layer_forward(layer, &a);
                // local head on the block output
                let (feat, pool_ctx) = les_features(layer, &out);
                let head_w = match layer {
                    FLayer::Conv { head, .. } | FLayer::Linear { head, .. } => head,
                };
                let local_logits = f::matmul(&feat, head_w);
                let (loss, dlog) = f::softmax_ce(&local_logits, &labels);
                batch_loss += loss;
                let ghead = f::matmul_at_b(&feat, &dlog);
                let dfeat = f::matmul_a_bt(&dlog, head_w);
                // back through local pooling + the block's own layers
                let d = les_backward(layer, &cache, &out, dfeat, pool_ctx);
                match layer {
                    FLayer::Conv { w, padding, head, .. } => {
                        let gw = f::conv2d_weight_grad(&cache.a_in, &d, 3,
                                                       *padding);
                        opt.update(2 * li, w, &gw);
                        opt.update(2 * li + 1, head, &ghead);
                    }
                    FLayer::Linear { w, head } => {
                        let gw = f::matmul_at_b(&cache.a_in, &d);
                        opt.update(2 * li, w, &gw);
                        opt.update(2 * li + 1, head, &ghead);
                    }
                }
                a = out;
            }
            // output head on detached features
            let (b_, f_) = a.batch_feat();
            let a = a.reshaped(&[b_, f_]);
            let logits = f::matmul(&a, &net.head);
            train_correct += argmax_correct(&logits, &labels);
            train_seen += labels.len();
            let (loss, dlog) = f::softmax_ce(&logits, &labels);
            let ghead = f::matmul_at_b(&a, &dlog);
            opt.update(2 * nl, &mut net.head, &ghead);
            losses.push(batch_loss + loss);
        }
    }
    FpResult {
        test_acc: net.accuracy(test, batch),
        train_acc: train_correct as f64 / train_seen.max(1) as f64,
        losses,
    }
}

/// Adaptive max-pool + flatten for conv LES heads (mirrors the integer
/// learning layers); identity for linear.
fn les_features(layer: &FLayer, out: &FTensor)
                -> (FTensor, Option<(Vec<u32>, Vec<usize>, usize, usize)>) {
    match layer {
        FLayer::Linear { .. } => {
            let (b, f_) = out.batch_feat();
            (out.clone().reshaped(&[b, f_]), None)
        }
        FLayer::Conv { lr_pool: (s, k), .. } => {
            let (s, k) = (*s, k.max(&1).to_owned());
            let (b, c, h, w) = (out.shape[0], out.shape[1], out.shape[2],
                                out.shape[3]);
            if k <= 1 && h == s && w == s {
                return (out.clone().reshaped(&[b, c * s * s]), None);
            }
            let (pooled, arg) = f::maxpool2d(out, k, k);
            let (ph, pw) = (pooled.shape[2], pooled.shape[3]);
            let mut feat = vec![0f32; b * c * s * s];
            for bc in 0..b * c {
                for oy in 0..s {
                    for ox in 0..s {
                        feat[bc * s * s + oy * s + ox] =
                            pooled.data[bc * ph * pw + oy * pw + ox];
                    }
                }
            }
            (
                Tensor::from_vec(&[b, c * s * s], feat),
                Some((arg, out.shape.clone(), s, k)),
            )
        }
    }
}

fn les_backward(layer: &FLayer, cache: &FCache, out: &FTensor, dfeat: FTensor,
                pool_ctx: Option<(Vec<u32>, Vec<usize>, usize, usize)>)
                -> FTensor {
    let d_out = match (layer, pool_ctx) {
        (FLayer::Linear { .. }, _) | (FLayer::Conv { .. }, None) => {
            dfeat.reshaped(&out.shape)
        }
        (FLayer::Conv { .. }, Some((arg, shape, s, k))) => {
            let (b, c, h, w) = (shape[0], shape[1], shape[2], shape[3]);
            let (ph, pw) = ((h - k) / k + 1, (w - k) / k + 1);
            let mut dg = vec![0f32; b * c * ph * pw];
            for bc in 0..b * c {
                for oy in 0..s {
                    for ox in 0..s {
                        dg[bc * ph * pw + oy * pw + ox] =
                            dfeat.data[bc * s * s + oy * s + ox];
                    }
                }
            }
            f::maxpool2d_bwd(
                &Tensor::from_vec(&[b, c, ph, pw], dg),
                &arg,
                &shape,
                k,
                k,
            )
        }
    };
    // back through the block's own pool + activation
    match layer {
        FLayer::Conv { pool, .. } => {
            let d = if *pool {
                let (arg, pre) = cache.pool_arg.as_ref().unwrap();
                f::maxpool2d_bwd(&d_out, arg, pre, 2, 2)
            } else {
                d_out
            };
            f::leaky_relu_bwd(cache.z.as_ref().unwrap(), &d, 0.1)
        }
        FLayer::Linear { .. } => {
            f::leaky_relu_bwd(cache.z.as_ref().unwrap(), &d_out, 0.1)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::nn::zoo;

    fn tiny_data() -> (Dataset, Dataset) {
        let mut ds = synthetic::by_name("tiny", 900, 2).unwrap();
        ds.mad_normalize();
        ds.split_test(180)
    }

    #[test]
    fn bp_learns_tiny() {
        let (tr, te) = tiny_data();
        let mut net = FpNet::new(zoo::get("tinycnn").unwrap(), 1);
        let res = train_bp(&mut net, &tr, &te, 12, 32, 1e-3, 3);
        assert!(res.test_acc > 0.5, "fp bp acc {}", res.test_acc);
        assert!(res.losses.last().unwrap() < res.losses.first().unwrap());
    }

    #[test]
    fn les_learns_tiny() {
        let (tr, te) = tiny_data();
        let mut net = FpNet::new(zoo::get("tinycnn").unwrap(), 1);
        let res = train_les(&mut net, &tr, &te, 12, 32, 1e-3, 3);
        assert!(res.test_acc > 0.5, "fp les acc {}", res.test_acc);
    }

    #[test]
    fn bp_learns_mlp() {
        let (tr, te) = tiny_data();
        let mut net = FpNet::new(zoo::get("mlp1-mini").unwrap(), 1);
        let res = train_bp(&mut net, &tr, &te, 12, 32, 1e-3, 3);
        assert!(res.test_acc > 0.5, "fp bp mlp acc {}", res.test_acc);
    }
}
