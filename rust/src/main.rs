//! `nitro` — the NITRO-D coordinator CLI.
//!
//! Subcommands:
//!   train       train a preset on a dataset (native or pjrt engine)
//!   eval        evaluate a checkpoint
//!   serve       serve NITRO1 checkpoints (JSON lines on stdio or TCP)
//!   predict     one-shot batch scoring of a checkpoint
//!   loadgen     open-loop load generator against `nitro serve --listen`
//!   experiment  regenerate a paper table/figure (table1..fig3|all)
//!   run-spec    execute a declarative experiment spec (experiments/*.json)
//!   zoo         list model presets and parameter counts
//!   runtime     PJRT smoke check: load + execute the artifacts
//!   lint        integer-discipline static analyzer over rust/src

use nitro::coordinator::engine::{Engine, PjrtEngine};
use nitro::coordinator::experiments::{self, ExpCtx, Scale};
use nitro::coordinator::kernelbench;
use nitro::coordinator::runner::{self, RunnerOpts};
use nitro::coordinator::serve::{self, flags as serveflags, loadgen,
                                ModelRegistry, ServeConfig};
use nitro::coordinator::spec::ExperimentSpec;
use nitro::data::loader;
use nitro::nn::{zoo, Hyper, Network};
use nitro::train::{checkpoint, dist, evaluate, fit, fit_dist, NullSink,
                   Scheduler, TrainConfig};
use nitro::util::cli::Command;
use nitro::util::fault::FaultPlan;
use nitro::util::rng::Pcg32;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match argv.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&argv[1..]),
        Some("eval") => cmd_eval(&argv[1..]),
        Some("serve") => cmd_serve(&argv[1..]),
        Some("predict") => cmd_predict(&argv[1..]),
        Some("loadgen") => cmd_loadgen(&argv[1..]),
        Some("experiment") => cmd_experiment(&argv[1..]),
        Some("run-spec") => cmd_run_spec(&argv[1..]),
        Some("bench-kernels") => cmd_bench_kernels(&argv[1..]),
        Some("zoo") => cmd_zoo(),
        Some("runtime") => cmd_runtime(&argv[1..]),
        Some("lint") => cmd_lint(&argv[1..]),
        Some("-h") | Some("--help") | None => {
            eprintln!("{}", USAGE);
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand '{other}'\n\n{USAGE}");
            2
        }
    };
    std::process::exit(code);
}

const USAGE: &str = "nitro — NITRO-D: native integer-only CNN training

Usage: nitro <subcommand> [options]

Subcommands:
  train       train a preset (see `nitro train --help`); supports
              multi-process --distributed ranks over TCP with crash-safe
              --checkpoint / --resume and deterministic --fault-plan
              injection
  eval        evaluate a checkpoint on a dataset
  serve       serve NITRO1 checkpoints: sharded micro-batched integer
              inference over JSON lines (stdin/stdout or --listen TCP),
              with hot reload and latency-budget load shedding
  predict     one-shot batch scoring: `nitro predict <ckpt> <input.json>`
  loadgen     coordinated-omission-safe open-loop load generator against
              a running `nitro serve --listen`
  experiment  regenerate a paper table/figure: table1 table2 table8
              table9 fig2-left fig2-right fig3 all
  run-spec    execute a declarative experiment spec, e.g.
              `nitro run-spec experiments/smoke.json`
  bench-kernels
              time the integer kernel hot paths (pool vs per-call spawn,
              workspace reuse) and emit BENCH_kernels.json +
              BENCH_serve.json
  zoo         list model presets
  runtime     PJRT smoke check over artifacts/<preset>
  lint        integer-discipline static analyzer over rust/src (exit 0
              clean, 1 violations, 2 internal error); --json for the
              machine-readable report, --fix-allow to insert placeholder
              escape comments
";

fn fail(e: String) -> i32 {
    eprintln!("{e}");
    2
}

fn cmd_train(argv: &[String]) -> i32 {
    let cmd = Command::new("nitro train", "train a NITRO-D network")
        .opt("preset", "tinycnn", "model preset (see `nitro zoo`)")
        .opt("dataset", "tiny", "mnist|fashion-mnist|cifar10|tiny|<synthetic>")
        .opt("epochs", "10", "training epochs")
        .opt("batch", "64", "batch size")
        .opt("gamma-inv", "512", "inverse learning rate")
        .opt("eta-fw-inv", "0", "forward-layer inverse decay (0 = off)")
        .opt("eta-lr-inv", "0", "learning-layer inverse decay (0 = off)")
        .opt("p-c", "0.0", "conv-block dropout rate")
        .opt("p-l", "0.0", "linear-block dropout rate")
        .opt("n-train", "2000", "synthetic train samples")
        .opt("n-test", "400", "synthetic test samples")
        .opt("seed", "42", "PRNG seed")
        .opt("save", "", "checkpoint output path")
        .opt("engine", "native", "native|pjrt (pjrt needs artifacts)")
        .opt("artifacts", "artifacts", "artifacts dir for --engine pjrt")
        .opt("scheduler", "pipelined",
             "LES scheduler: sequential|block-parallel|pipelined \
              (bit-identical results)")
        .opt("replicas", "1",
             "data-parallel replica count (bit-identical to 1: integer \
              gradient all-reduce is exact)")
        .opt("bits", "",
             "W/A/G/E bitwidth rails: 'N' (uniform W/A) or 'W/A/G/E', \
              e.g. 8 or 8/8/64/64 ('' = full-width default)")
        .flag("distributed",
              "run as one rank of a multi-process group over TCP \
               (needs --peers); byte-identical to --replicas <world>")
        .opt("rank", "0", "this process's rank under --distributed")
        .opt("peers", "",
             "comma-separated host:port list, one entry per rank, \
              identical on every rank (rank r binds peers[r])")
        .opt("checkpoint", "",
             "crash-safe checkpoint path, rewritten atomically every \
              --checkpoint-every epochs (fsynced file + directory)")
        .opt("checkpoint-every", "0",
             "periodic checkpoint cadence in epochs (0 = off)")
        .flag("resume",
              "reload weights + training state from --checkpoint and \
               finish the run byte-identically to an uninterrupted one")
        .opt("fault-plan", "",
             "deterministic fault injection for --distributed: inline \
              JSON rules or a file path (env NITRO_FAULT when unset)")
        .flag("sequential", "shorthand for --scheduler sequential")
        .flag("quiet", "suppress per-epoch logs");
    let p = match cmd.parse(argv) {
        Ok(p) => p,
        Err(e) => return fail(e),
    };
    let run = || -> Result<(), String> {
        let preset = p.get("preset").to_string();
        let seed = p.get_i64("seed")? as u64;
        let (mut tr, mut te) = loader::load(
            p.get("dataset"), "data", p.get_usize("n-train")?,
            p.get_usize("n-test")?, seed)?;
        tr.mad_normalize();
        te.mad_normalize();
        let hp = Hyper {
            gamma_inv: p.get_i64("gamma-inv")?,
            eta_fw_inv: p.get_i64("eta-fw-inv")?,
            eta_lr_inv: p.get_i64("eta-lr-inv")?,
        };
        match p.get("engine") {
            "native" => {
                let mut spec = zoo::get(&preset)
                    .ok_or_else(|| format!("unknown preset '{preset}'"))?;
                if !p.get("bits").is_empty() {
                    let cfg = nitro::nn::spec::BitwidthCfg::parse_label(
                        p.get("bits"))?;
                    spec = spec.with_bits(
                        nitro::nn::spec::BitsPlan::uniform(cfg));
                }
                println!(
                    "training {preset} ({} params, {} at inference) on {}",
                    spec.param_count(),
                    spec.inference_param_count(),
                    tr.name
                );
                let mut net = Network::new(spec, seed);
                net.set_dropout(p.get_f64("p-c")?, p.get_f64("p-l")?);
                let ckpt = p.get("checkpoint");
                let resume = if p.has("resume") {
                    if ckpt.is_empty() {
                        return Err("--resume needs --checkpoint".into());
                    }
                    let st = checkpoint::load_state(ckpt)?.ok_or_else(
                        || format!("{ckpt}: no training state to \
                                    resume from"))?;
                    checkpoint::load(&mut net, ckpt)?;
                    println!("resuming at epoch {}", st.epoch);
                    Some(st)
                } else {
                    None
                };
                let cfg = TrainConfig {
                    epochs: p.get_usize("epochs")?,
                    batch: p.get_usize("batch")?,
                    hyper: hp,
                    seed,
                    scheduler: if p.has("sequential") {
                        Scheduler::Sequential
                    } else {
                        Scheduler::parse(p.get("scheduler"))?
                    },
                    replicas: match p.get_usize("replicas")? {
                        0 => return Err(
                            "--replicas must be >= 1".to_string()),
                        n => n,
                    },
                    verbose: !p.has("quiet"),
                    resume,
                    checkpoint_path: (!ckpt.is_empty())
                        .then(|| ckpt.to_string()),
                    checkpoint_every: p.get_usize("checkpoint-every")?,
                    ..Default::default()
                };
                let res = if p.has("distributed") {
                    let fault = match p.get("fault-plan") {
                        "" => FaultPlan::from_env()?.unwrap_or_default(),
                        arg => FaultPlan::from_arg(arg)?,
                    };
                    let dcfg = dist::DistConfig {
                        rank: p.get_usize("rank")?,
                        peers: p
                            .get("peers")
                            .split(',')
                            .map(|s| s.trim().to_string())
                            .filter(|s| !s.is_empty())
                            .collect(),
                        fault,
                        // a CLI rank hit by an injected crash dies like
                        // a real process: exit code 43, no cleanup
                        crash_process: true,
                        ..Default::default()
                    };
                    let rank = dcfg.rank;
                    let world = dcfg.peers.len();
                    let mut dt = dist::DistTrainer::new(&net, dcfg)?;
                    println!("rank {rank}/{world} listening; \
                              peers connecting...");
                    dt.wait_connected(10_000);
                    let res =
                        fit_dist(&mut net, &tr, &te, &cfg, &mut dt,
                                 &mut NullSink);
                    let st = dt.stats();
                    println!(
                        "rank {rank}: remote shards {} solo {} \
                         reconnects {} views {}",
                        st.remote_shards_used, st.solo_shards,
                        st.reconnects, st.view
                    );
                    res
                } else {
                    fit(&mut net, &tr, &te, &cfg)
                };
                if res.interrupted {
                    return Err("training interrupted by injected \
                                crash".to_string());
                }
                println!("final test accuracy: {:.2}%",
                         res.final_test_acc * 100.0);
                let save = p.get("save");
                if !save.is_empty() {
                    checkpoint::save(&net, save)?;
                    println!("checkpoint -> {save}");
                }
            }
            "pjrt" => {
                let dir = format!("{}/{preset}", p.get("artifacts"));
                let mut eng = PjrtEngine::load(&dir, seed)?;
                let batch = eng.manifest.batch;
                println!(
                    "training {preset} via PJRT artifacts ({dir}), batch {batch}"
                );
                let epochs = p.get_usize("epochs")?;
                let mut rng = Pcg32::with_stream(seed, 0x7e);
                let flatten = eng.manifest.input_shape.len() == 1;
                for epoch in 0..epochs {
                    let mut head_loss = 0f64;
                    let mut correct = 0usize;
                    let mut seen = 0usize;
                    for (x, labels) in
                        nitro::data::Batcher::new(&tr, batch, flatten, &mut rng)
                    {
                        if labels.len() < batch {
                            continue; // artifacts are shape-specialized
                        }
                        let (_, hl, c) = eng.train_batch(&x, &labels, &hp);
                        head_loss += hl as f64;
                        correct += c;
                        seen += labels.len();
                    }
                    if !p.has("quiet") {
                        eprintln!(
                            "[epoch {epoch:>3}] head_loss {head_loss:>12.0} \
                             train_acc {:.4}",
                            correct as f64 / seen.max(1) as f64
                        );
                    }
                }
                let mut correct = 0usize;
                let mut seen = 0usize;
                for (x, labels) in
                    nitro::data::Batcher::sequential(&te, batch, flatten)
                {
                    if labels.len() < batch {
                        continue;
                    }
                    let yhat = eng.infer(&x);
                    correct += nitro::nn::block::count_correct(&yhat, &labels);
                    seen += labels.len();
                }
                println!("final test accuracy (pjrt): {:.2}%",
                         100.0 * correct as f64 / seen.max(1) as f64);
            }
            other => return Err(format!("unknown engine '{other}'")),
        }
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => fail(e),
    }
}

fn cmd_eval(argv: &[String]) -> i32 {
    let cmd = Command::new("nitro eval", "evaluate a checkpoint")
        .opt("preset", "tinycnn", "model preset the checkpoint was built from")
        .opt("dataset", "tiny", "dataset name")
        .opt("n-test", "400", "synthetic test samples")
        .opt("seed", "42", "dataset seed")
        .opt("bits", "",
             "W/A/G/E bitwidth rails the checkpoint was trained with \
              ('' = full-width default; must match the NITRO1 header)")
        .positional("checkpoint", "path to .ckpt file");
    let p = match cmd.parse(argv) {
        Ok(p) => p,
        Err(e) => return fail(e),
    };
    let run = || -> Result<(), String> {
        let ckpt = p.positionals.first().ok_or("missing checkpoint path")?;
        let seed = p.get_i64("seed")? as u64;
        let mut spec = zoo::get(p.get("preset"))
            .ok_or_else(|| format!("unknown preset '{}'", p.get("preset")))?;
        if !p.get("bits").is_empty() {
            let cfg = nitro::nn::spec::BitwidthCfg::parse_label(
                p.get("bits"))?;
            spec = spec.with_bits(nitro::nn::spec::BitsPlan::uniform(cfg));
        }
        let mut net = Network::new(spec, 0);
        checkpoint::load(&mut net, ckpt)?;
        let (_, mut te) = loader::load(p.get("dataset"), "data", 16,
                                       p.get_usize("n-test")?, seed)?;
        te.mad_normalize();
        println!("accuracy: {:.2}%", evaluate(&net, &te, 64) * 100.0);
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => fail(e),
    }
}

fn cmd_serve(argv: &[String]) -> i32 {
    let cmd = serveflags::command(
        "nitro serve",
        "serve NITRO1 checkpoints with sharded micro-batched integer \
         inference",
        serveflags::SERVE,
    )
    .positional("checkpoints",
                "deprecated: bare checkpoint path(s); use --models");
    let p = match cmd.parse(argv) {
        Ok(p) => p,
        Err(e) => return fail(e),
    };
    let run = || -> Result<(), String> {
        let registry = match (p.get("models"), p.positionals.first()) {
            ("", None) => {
                return Err("missing --models name=path[,name=path...] \
                            (or a deprecated positional path list)"
                    .to_string())
            }
            ("", Some(paths)) => {
                eprintln!(
                    "nitro serve: deprecation: positional checkpoint \
                     paths; use --models name=path[,name=path...]"
                );
                ModelRegistry::from_paths(paths)?
            }
            (spec, None) => ModelRegistry::from_spec(spec)?,
            (_, Some(_)) => {
                return Err("--models and positional checkpoint paths \
                            are mutually exclusive"
                    .to_string())
            }
        };
        let cfg = ServeConfig::builder()
            .max_batch(p.get_usize("max-batch")?)
            .max_wait_us(p.get_u64("max-wait-us")?)
            .max_request_samples(p.get_usize("max-request")?)
            .shards(p.get_usize("shards")?)
            .queue_budget_ms(p.get_f64("queue-budget-ms")?)
            .io_timeout_ms(p.get_u64("io-timeout-ms")?)
            .build()?;
        let sighup = p.has("reload-on-sighup");
        match p.get("listen") {
            "" => serve::serve_stdio(registry, cfg),
            addr => serve::serve_tcp(registry, cfg, addr, sighup),
        }
    };
    match run() {
        Ok(()) => 0,
        Err(e) => fail(e),
    }
}

fn cmd_predict(argv: &[String]) -> i32 {
    let cmd = serveflags::command(
        "nitro predict",
        "one-shot batch scoring of a NITRO1 checkpoint",
        serveflags::PREDICT,
    )
    .positional("checkpoint", "path to a NITRO1 checkpoint")
    .positional("input",
                "JSON input: flat int array, array of per-sample arrays, \
                 or {\"inputs\": ...}; '-' reads stdin");
    let p = match cmd.parse(argv) {
        Ok(p) => p,
        Err(e) => return fail(e),
    };
    let run = || -> Result<(), String> {
        let ckpt = p.positionals.first().ok_or("missing checkpoint path")?;
        let input = p.positionals.get(1).ok_or("missing input path")?;
        let resp = serve::predict_once(ckpt, input)?;
        match p.get("out") {
            "" => println!("{}", resp.pretty().trim_end()),
            path => std::fs::write(path, resp.pretty())
                .map_err(|e| format!("write {path}: {e}"))?,
        }
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => fail(e),
    }
}

fn cmd_loadgen(argv: &[String]) -> i32 {
    let cmd = serveflags::command(
        "nitro loadgen",
        "open-loop load generator: offers a fixed arrival schedule and \
         charges server backlog to the latency percentiles \
         (coordinated-omission-safe)",
        serveflags::LOADGEN,
    );
    let p = match cmd.parse(argv) {
        Ok(p) => p,
        Err(e) => return fail(e),
    };
    let run = || -> Result<(), String> {
        let opts = loadgen::LoadgenOpts {
            addr: p.get("connect").to_string(),
            rate: p.get_f64("rate")?,
            duration_s: p.get_f64("duration")?,
            connections: p.get_usize("connections")?.max(1),
            model: match p.get("model") {
                "" => None,
                m => Some(m.to_string()),
            },
            req_samples: p.get_usize("req-samples")?.max(1),
            seed: p.get_u64("seed")?,
        };
        let rep = loadgen::run(&opts)?;
        if rep.ok + rep.shed == 0 {
            return Err(format!(
                "no request succeeded or was shed ({} errors) — is the \
                 server healthy?",
                rep.errors
            ));
        }
        println!(
            "loadgen: offered {} at {:.0} rps over {} conn(s): {} ok, \
             {} shed, {} errors, {} late sends",
            rep.offered, rep.offered_rps, rep.connections, rep.ok,
            rep.shed, rep.errors, rep.late_sends
        );
        println!(
            "latency (from scheduled arrival): p50 {}us  p99 {}us  \
             p999 {}us  max {}us",
            rep.hist.quantile(0.50) / 1000,
            rep.hist.quantile(0.99) / 1000,
            rep.hist.quantile(0.999) / 1000,
            rep.hist.max() / 1000
        );
        let record = nitro::util::jsonio::Json::obj(vec![
            ("schema_version",
             nitro::util::jsonio::Json::Int(serve::SCHEMA_VERSION)),
            ("experiment",
             nitro::util::jsonio::Json::Str("serve_loadgen".to_string())),
            ("target",
             nitro::util::jsonio::Json::Str(opts.addr.clone())),
            ("open_loop", rep.json()),
        ]);
        match p.get("out") {
            "" => println!("{}", record.pretty().trim_end()),
            path => {
                std::fs::write(path, record.pretty())
                    .map_err(|e| format!("write {path}: {e}"))?;
                println!("-> {path}");
            }
        }
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => fail(e),
    }
}

fn cmd_experiment(argv: &[String]) -> i32 {
    let cmd = Command::new("nitro experiment",
                           "regenerate a paper table/figure")
        .opt("scale", "quick", "quick (narrow presets) | full (paper width)")
        .opt("seed", "42", "PRNG seed")
        .opt("epochs", "0", "override epochs (0 = scale default)")
        .positional(
            "name",
            "table1|table2|table8|table9|fig2-left|fig2-right|fig3|all",
        );
    let p = match cmd.parse(argv) {
        Ok(p) => p,
        Err(e) => return fail(e),
    };
    let run = || -> Result<(), String> {
        let name = p.positionals.first().ok_or("missing experiment name")?;
        let scale = Scale::parse(p.get("scale"))?;
        let ctx = ExpCtx::new(scale, p.get_i64("seed")? as u64,
                              p.get_usize("epochs")?);
        experiments::run(name, &ctx)
    };
    match run() {
        Ok(()) => 0,
        Err(e) => fail(e),
    }
}

fn cmd_run_spec(argv: &[String]) -> i32 {
    let cmd = Command::new("nitro run-spec",
                           "execute a declarative experiment spec")
        .opt("scale", "", "override the spec's scale: quick|full")
        .opt("seed", "", "override the spec's seed list with one seed")
        .opt("epochs", "0", "override epochs (0 = spec defaults)")
        .opt("scheduler", "",
             "override the spec's LES scheduler: \
              sequential|block-parallel|pipelined")
        .opt("replicas", "0",
             "override the spec's data-parallel replica count \
              (0 = spec default; metric-identical)")
        .opt("ranks", "0",
             "override the spec's loopback distributed world size \
              (0 = spec default; metric-identical)")
        .opt("bits", "",
             "override the spec's W/A/G/E bitwidth sweep with one cell: \
              'N' (uniform W/A, e.g. 8) or 'W/A/G/E' (e.g. 8/8/64/64); \
              changes the arithmetic, unlike the knobs above")
        .opt("out-dir", "results", "directory for per-run records")
        .opt("bench-dir", ".", "directory for the aggregate BENCH json")
        .flag("verbose", "per-epoch trainer logs")
        .positional("spec", "path to an experiments/*.json spec file");
    let p = match cmd.parse(argv) {
        Ok(p) => p,
        Err(e) => return fail(e),
    };
    let run = || -> Result<(), String> {
        let path = p.positionals.first().ok_or("missing spec path")?;
        let spec = ExperimentSpec::load(path)?;
        let scale = match p.get("scale") {
            "" => None,
            s => Some(Scale::parse(s)?),
        };
        let seed = match p.get("seed") {
            "" => None,
            _ => Some(p.get_u64("seed")?),
        };
        let scheduler = match p.get("scheduler") {
            "" => None,
            s => Some(Scheduler::parse(s)?),
        };
        let opts = RunnerOpts {
            scale,
            seed,
            epochs: p.get_usize("epochs")?,
            scheduler,
            replicas: match p.get_usize("replicas")? {
                0 => None,
                n => Some(n),
            },
            ranks: match p.get_usize("ranks")? {
                0 => None,
                n => Some(n),
            },
            bits: match p.get("bits") {
                "" => None,
                s => Some(nitro::nn::spec::BitsPlan::uniform(
                    nitro::nn::spec::BitwidthCfg::parse_label(s)?,
                )),
            },
            out_dir: p.get("out-dir").to_string(),
            bench_dir: p.get("bench-dir").to_string(),
            verbose: p.has("verbose"),
        };
        runner::execute(&spec, &opts).map(|_| ())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => fail(e),
    }
}

fn cmd_bench_kernels(argv: &[String]) -> i32 {
    let cmd = Command::new("nitro bench-kernels",
                           "time the integer kernel hot paths")
        .opt("budget", "0",
             "per-benchmark seconds (0 = NITRO_BENCH_BUDGET or 1.0)")
        .opt("out", "BENCH_kernels.json", "output JSON path")
        .opt("baseline", "",
             "baseline BENCH_kernels.json for an advisory ±30% comparison")
        .opt("serve-out", "BENCH_serve.json",
             "output path for the serve-throughput record \
              ('' skips the serve section)")
        .flag("write-baseline",
              "also write the record to experiments/bench_baseline.json \
               (commit it to seed the CI advisory gate)")
        .flag("quick", "small-shape subset, no full train-step benches");
    let p = match cmd.parse(argv) {
        Ok(p) => p,
        Err(e) => return fail(e),
    };
    let run = || -> Result<(), String> {
        let budget = p.get_f64("budget")?;
        let opts = kernelbench::Opts {
            budget_s: if budget > 0.0 { Some(budget) } else { None },
            out: p.get("out").to_string(),
            baseline: match p.get("baseline") {
                "" => None,
                b => Some(b.to_string()),
            },
            write_baseline: p.has("write-baseline"),
            quick: p.has("quick"),
            serve_out: p.get("serve-out").to_string(),
        };
        kernelbench::run(&opts).map(|_| ())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => fail(e),
    }
}

fn cmd_zoo() -> i32 {
    println!("{:<22} {:>12} {:>14} blocks", "preset", "params",
             "infer params");
    for name in zoo::names() {
        let spec = zoo::get(name).unwrap();
        println!(
            "{:<22} {:>12} {:>14} {}",
            name,
            spec.param_count(),
            spec.inference_param_count(),
            spec.blocks.len()
        );
    }
    0
}

fn cmd_lint(argv: &[String]) -> i32 {
    let cmd = Command::new(
        "nitro lint",
        "static analyzer for the integer-discipline contract: \
         int-discipline, no-float, no-panic, determinism",
    )
    .opt("root", "",
         "repo root to scan (default: walk up from the current \
          directory until rust/src is found)")
    .flag("json", "emit the schema-versioned JSON report on stdout")
    .flag("fix-allow",
          "insert placeholder escape comments above each violation; \
           the tree stays red until the FIXME reasons are rewritten");
    let p = match cmd.parse(argv) {
        Ok(p) => p,
        Err(e) => return fail(e),
    };
    let root = match p.get("root") {
        "" => match find_root() {
            Some(r) => r,
            None => {
                return fail(
                    "nitro lint: no rust/src above the current directory \
                     (use --root)"
                        .to_string(),
                )
            }
        },
        r => std::path::PathBuf::from(r),
    };
    let report = match nitro::analysis::run(&root) {
        Ok(r) => r,
        Err(e) => return fail(e),
    };
    if p.has("fix-allow") {
        match nitro::analysis::fix_allow(&root, &report) {
            Ok(n) => eprintln!(
                "nitro lint: inserted {n} placeholder allow comment(s); \
                 rewrite each FIXME reason before committing"
            ),
            Err(e) => return fail(e),
        }
    }
    if p.has("json") {
        println!("{}", report.to_json().dump());
    } else {
        print!("{}", report.text());
    }
    if report.findings.is_empty() {
        0
    } else {
        1
    }
}

/// Walk up from the current directory to the first ancestor containing
/// `rust/src` — the repo root, whether invoked from it or from `rust/`.
fn find_root() -> Option<std::path::PathBuf> {
    let mut dir = std::env::current_dir().ok()?;
    loop {
        if dir.join("rust").join("src").is_dir() {
            return Some(dir);
        }
        if !dir.pop() {
            return None;
        }
    }
}

fn cmd_runtime(argv: &[String]) -> i32 {
    let cmd = Command::new("nitro runtime", "PJRT artifact smoke check")
        .opt("artifacts", "artifacts", "artifacts root")
        .opt("preset", "tinycnn", "preset to load");
    let p = match cmd.parse(argv) {
        Ok(p) => p,
        Err(e) => return fail(e),
    };
    let run = || -> Result<(), String> {
        let dir = format!("{}/{}", p.get("artifacts"), p.get("preset"));
        let mut eng = PjrtEngine::load(&dir, 7)?;
        let m = eng.manifest.clone();
        println!("loaded {} blocks + head + infer from {dir} (batch {})",
                 m.blocks.len(), m.batch);
        let mut rng = Pcg32::new(1);
        let mut shape = vec![m.batch];
        shape.extend(&m.input_shape);
        let n: usize = shape.iter().product();
        let x = nitro::tensor::ITensor::from_vec(
            &shape, (0..n).map(|_| rng.range_i32(-127, 127)).collect());
        let labels: Vec<usize> =
            (0..m.batch).map(|i| i % m.num_classes).collect();
        let hp = Hyper::default();
        let (block_loss, head_loss, _) = eng.train_batch(&x, &labels, &hp);
        println!("train step OK: block losses {block_loss:?}, head {head_loss}");
        let yhat = eng.infer(&x);
        println!("infer OK: yhat shape {:?}", yhat.shape);
        println!("runtime smoke check PASSED ({})", eng.name());
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => fail(e),
    }
}
