//! Artifact manifest (`artifacts/<preset>/manifest.json`): the contract
//! between `python/compile/aot.py` and the Rust coordinator. Every topology
//! constant the coordinator needs (shapes, SF, alpha_inv, mu, AF, pooling
//! geometry) is carried here, so the Rust side never re-derives them from
//! Python — it *verifies* them against its own zoo instead (tests/golden.rs).

use crate::util::jsonio::Json;

#[derive(Clone, Debug)]
pub struct BlockEntry {
    pub index: usize,
    pub kind: String, // "conv" | "linear"
    pub artifact_fwd: String,
    pub artifact_train: String,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    pub wf_shape: Vec<usize>,
    pub wl_shape: Vec<usize>,
    pub sf: i64,
    pub alpha_inv: i64,
    pub mu: i32,
    pub pool: bool,
    pub lr_pool_s: usize,
    pub lr_pool_k: usize,
}

#[derive(Clone, Debug)]
pub struct HeadEntry {
    pub artifact_fwd: String,
    pub artifact_train: String,
    pub in_shape: Vec<usize>,
    pub w_shape: Vec<usize>,
    pub sf: i64,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub preset: String,
    pub batch: usize,
    pub num_classes: usize,
    pub input_shape: Vec<usize>,
    pub one_hot_value: i32,
    pub amplification_factor: i64,
    pub blocks: Vec<BlockEntry>,
    pub head: HeadEntry,
    pub infer: String,
    /// Directory the manifest was loaded from (artifact paths are relative
    /// to it).
    pub dir: String,
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Manifest, String> {
        let path = format!("{dir}/manifest.json");
        let j = Json::parse_file(&path)?;
        Self::from_json(&j, dir).map_err(|e| format!("{path}: {e}"))
    }

    pub fn from_json(j: &Json, dir: &str) -> Result<Manifest, String> {
        let blocks = j
            .req("blocks")?
            .as_array()
            .ok_or("blocks not an array")?
            .iter()
            .map(block_entry)
            .collect::<Result<Vec<_>, _>>()?;
        let h = j.req("head")?;
        let head = HeadEntry {
            artifact_fwd: req_str(h, "artifact_fwd")?,
            artifact_train: req_str(h, "artifact_train")?,
            in_shape: h.req("in_shape")?.usize_vec()?,
            w_shape: h.req("w_shape")?.usize_vec()?,
            sf: req_i64(h, "sf")?,
        };
        Ok(Manifest {
            preset: req_str(j, "preset")?,
            batch: req_i64(j, "batch")? as usize,
            num_classes: req_i64(j, "num_classes")? as usize,
            input_shape: j.req("input_shape")?.usize_vec()?,
            one_hot_value: req_i64(j, "one_hot_value")? as i32,
            amplification_factor: req_i64(j, "amplification_factor")?,
            blocks,
            head,
            infer: req_str(j, "infer")?,
            dir: dir.to_string(),
        })
    }

    pub fn artifact_path(&self, file: &str) -> String {
        format!("{}/{}", self.dir, file)
    }
}

fn block_entry(j: &Json) -> Result<BlockEntry, String> {
    Ok(BlockEntry {
        index: req_i64(j, "index")? as usize,
        kind: req_str(j, "kind")?,
        artifact_fwd: req_str(j, "artifact_fwd")?,
        artifact_train: req_str(j, "artifact_train")?,
        in_shape: j.req("in_shape")?.usize_vec()?,
        out_shape: j.req("out_shape")?.usize_vec()?,
        wf_shape: j.req("wf_shape")?.usize_vec()?,
        wl_shape: j.req("wl_shape")?.usize_vec()?,
        sf: req_i64(j, "sf")?,
        alpha_inv: req_i64(j, "alpha_inv")?,
        mu: req_i64(j, "mu")? as i32,
        pool: j.get("pool").and_then(|v| v.as_bool()).unwrap_or(false),
        lr_pool_s: j.get("lr_pool_s").and_then(|v| v.as_i64()).unwrap_or(0)
            as usize,
        lr_pool_k: j.get("lr_pool_k").and_then(|v| v.as_i64()).unwrap_or(0)
            as usize,
    })
}

fn req_str(j: &Json, k: &str) -> Result<String, String> {
    Ok(j.req(k)?
        .as_str()
        .ok_or_else(|| format!("'{k}' not a string"))?
        .to_string())
}

fn req_i64(j: &Json, k: &str) -> Result<i64, String> {
    j.req(k)?
        .as_i64()
        .ok_or_else(|| format!("'{k}' not an int"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "preset": "tinycnn", "batch": 8, "num_classes": 10,
      "input_shape": [1, 8, 8], "one_hot_value": 32,
      "amplification_factor": 640,
      "blocks": [
        {"index": 0, "kind": "conv", "artifact_fwd": "block0_fwd.hlo.txt",
         "artifact_train": "block0_train.hlo.txt",
         "in_shape": [8, 1, 8, 8], "out_shape": [8, 8, 4, 4],
         "wf_shape": [8, 1, 3, 3], "wl_shape": [128, 10],
         "sf": 2304, "alpha_inv": 10, "mu": 42,
         "pool": true, "lr_pool_s": 2, "lr_pool_k": 2}
      ],
      "head": {"artifact_fwd": "head_fwd.hlo.txt",
               "artifact_train": "head_train.hlo.txt",
               "in_shape": [8, 32], "w_shape": [32, 10], "sf": 8192},
      "infer": "infer.hlo.txt"
    }"#;

    #[test]
    fn parses_sample() {
        let j = Json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json(&j, "/x").unwrap();
        assert_eq!(m.preset, "tinycnn");
        assert_eq!(m.blocks.len(), 1);
        assert_eq!(m.blocks[0].sf, 2304);
        assert!(m.blocks[0].pool);
        assert_eq!(m.head.w_shape, vec![32, 10]);
        assert_eq!(m.artifact_path("infer.hlo.txt"), "/x/infer.hlo.txt");
        assert_eq!(m.amplification_factor, 640);
    }

    #[test]
    fn missing_key_is_clean_error() {
        let j = Json::parse(r#"{"preset": "x"}"#).unwrap();
        let err = Manifest::from_json(&j, "/x").unwrap_err();
        assert!(err.contains("missing key"), "{err}");
    }

    #[test]
    fn real_manifest_if_built() {
        // when `make artifacts` has run, parse the real thing
        for preset in ["tinycnn", "mlp1-mini"] {
            let dir = format!("artifacts/{preset}");
            if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
                let m = Manifest::load(&dir).unwrap();
                assert_eq!(m.preset, preset);
                assert_eq!(m.one_hot_value, 32);
                assert!(!m.blocks.is_empty());
            }
        }
    }
}
