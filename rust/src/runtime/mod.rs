//! PJRT runtime: load AOT-compiled HLO-text artifacts (produced by
//! `python/compile/aot.py`) and execute them on the CPU PJRT client via the
//! `xla` crate.
//!
//! Interchange is HLO **text**: jax >= 0.5 serializes HloModuleProto with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md).
//!
//! Python never runs on this path — the artifacts directory is the entire
//! build-time handoff.
//!
//! The `xla` crate is not available in the offline image, so the real
//! backend only compiles under the `pjrt` cargo feature (which requires
//! adding that dependency by hand — see README.md). The default build
//! substitutes a stub with the identical surface whose constructors return
//! `Err`, so the engines, CLI and examples compile and run unchanged;
//! `tests/pjrt.rs` is gated on the feature.

pub mod manifest;

use crate::tensor::{ITensor, LTensor};

pub use manifest::{BlockEntry, HeadEntry, Manifest};

/// Argument passed to an executable.
pub enum Arg {
    I32(ITensor),
    I64(LTensor),
    ScalarI64(i64),
}

/// A returned tensor: i32 or i64 depending on the artifact output.
#[derive(Debug, Clone, PartialEq)]
pub enum Out {
    I32(ITensor),
    I64(LTensor),
}

impl Out {
    pub fn as_i32(&self) -> &ITensor {
        match self {
            Out::I32(t) => t,
            Out::I64(_) => panic!("expected i32 output, got i64"),
        }
    }

    pub fn scalar_i64(&self) -> i64 {
        match self {
            Out::I64(t) => t.data[0],
            Out::I32(t) => t.data[0] as i64,
        }
    }
}

pub use backend::{Executable, Runtime};

#[cfg(feature = "pjrt")]
mod backend {
    use super::{Arg, Out};
    use crate::tensor::Tensor;

    /// A loaded, compiled artifact ready to execute.
    pub struct Executable {
        pub name: String,
        exe: xla::PjRtLoadedExecutable,
    }

    /// PJRT CPU client wrapper + executable cache.
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    impl Runtime {
        pub fn cpu() -> Result<Self, String> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| format!("PJRT cpu client: {e}"))?;
            Ok(Runtime { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile one HLO-text artifact.
        pub fn load(&self, path: &str) -> Result<Executable, String> {
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| format!("parse {path}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| format!("compile {path}: {e}"))?;
            Ok(Executable { name: path.to_string(), exe })
        }

        /// Execute with mixed-type args; returns the flattened output tuple.
        /// All aot.py artifacts are lowered with `return_tuple=True`.
        pub fn run(&self, exe: &Executable, args: &[Arg])
                   -> Result<Vec<Out>, String> {
            let literals: Vec<xla::Literal> = args
                .iter()
                .map(|a| match a {
                    Arg::I32(t) => {
                        let dims: Vec<i64> =
                            t.shape.iter().map(|&d| d as i64).collect();
                        xla::Literal::vec1(&t.data)
                            .reshape(&dims)
                            .map_err(|e| format!("reshape arg: {e}"))
                    }
                    Arg::I64(t) => {
                        let dims: Vec<i64> =
                            t.shape.iter().map(|&d| d as i64).collect();
                        xla::Literal::vec1(&t.data)
                            .reshape(&dims)
                            .map_err(|e| format!("reshape arg: {e}"))
                    }
                    Arg::ScalarI64(v) => Ok(xla::Literal::scalar(*v)),
                })
                .collect::<Result<_, _>>()?;
            let result = exe
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| format!("execute {}: {e}", exe.name))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| format!("fetch result: {e}"))?;
            let parts = lit
                .to_tuple()
                .map_err(|e| format!("untuple result: {e}"))?;
            parts.into_iter().map(|p| literal_to_out(&p)).collect()
        }
    }

    fn literal_to_out(lit: &xla::Literal) -> Result<Out, String> {
        let shape = lit
            .shape()
            .map_err(|e| format!("result shape: {e}"))?;
        let (ty, dims): (xla::ElementType, Vec<usize>) = match &shape {
            xla::Shape::Array(a) => (
                a.element_type(),
                a.dims().iter().map(|&d| d as usize).collect(),
            ),
            _ => return Err("tuple-in-tuple output unsupported".into()),
        };
        let dims = if dims.is_empty() { vec![1] } else { dims };
        match ty {
            xla::ElementType::S32 => {
                let data = lit
                    .to_vec::<i32>()
                    .map_err(|e| format!("read s32 result: {e}"))?;
                Ok(Out::I32(Tensor::from_vec(&dims, data)))
            }
            xla::ElementType::S64 => {
                let data = lit
                    .to_vec::<i64>()
                    .map_err(|e| format!("read s64 result: {e}"))?;
                Ok(Out::I64(Tensor::from_vec(&dims, data)))
            }
            other => Err(format!("unexpected result element type {other:?}")),
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod backend {
    //! Stub backend: same surface as the real one, every constructor
    //! returns `Err`. `PjrtEngine::load` therefore fails with a clear
    //! message at runtime instead of the whole crate failing to build.

    use super::{Arg, Out};

    const UNAVAILABLE: &str =
        "PJRT runtime not built: this binary was compiled without the \
         `pjrt` cargo feature (the `xla` crate is not available in this \
         image). Rebuild with `--features pjrt` after adding the xla \
         dependency — see README.md \"PJRT engine\".";

    /// Placeholder for a compiled artifact; never constructed.
    pub struct Executable {
        pub name: String,
    }

    /// Stub runtime: `cpu()` always errors.
    pub struct Runtime;

    impl Runtime {
        pub fn cpu() -> Result<Self, String> {
            Err(UNAVAILABLE.to_string())
        }

        pub fn platform(&self) -> String {
            "pjrt-unavailable".to_string()
        }

        pub fn load(&self, _path: &str) -> Result<Executable, String> {
            Err(UNAVAILABLE.to_string())
        }

        pub fn run(&self, _exe: &Executable, _args: &[Arg])
                   -> Result<Vec<Out>, String> {
            Err(UNAVAILABLE.to_string())
        }
    }
}

#[cfg(test)]
mod tests {
    // PJRT-dependent tests live in rust/tests/pjrt.rs (integration, gated
    // on the `pjrt` feature) so unit test runs stay fast; manifest parsing
    // is tested in manifest.rs.

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_missing_feature() {
        let err = super::Runtime::cpu().err().unwrap();
        assert!(err.contains("pjrt"), "{err}");
    }
}
