//! Dense tensor substrate: integer (`i32`/`i64`) and `f32` tensors with the
//! contraction kernels NITRO-D needs.
//!
//! Numeric-format contract (DESIGN.md): activations/weights live in `i32`
//! (logical int8/int16), contractions accumulate in `i64`, floor-division
//! rescales back down. The op set mirrors `python/compile/kernels/ref.py`
//! bit-exactly — verified against `artifacts/golden/ops.json`.

// The only crate module allowed to contain `unsafe` SIMD intrinsics;
// everything else is covered by the crate-root `#![deny(unsafe_code)]`.
#[allow(unsafe_code)]
pub mod backend;
pub mod ops_f32;
pub mod ops_int;

pub use backend::{kernels, Isa, KernelBackend};
pub use ops_int::*;

/// Row-major dense tensor. `T` is one of `i32`, `i64`, `f32`.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor<T> {
    pub shape: Vec<usize>,
    pub data: Vec<T>,
}

pub type ITensor = Tensor<i32>;
pub type LTensor = Tensor<i64>;
pub type FTensor = Tensor<f32>;

impl<T: Copy + Default> Tensor<T> {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![T::default(); n] }
    }

    /// Zero-element tensor: the seed value for buffers that are filled by
    /// `Dataset::gather_into` / grown in place (batch recycling).
    pub fn empty() -> Self {
        Tensor { shape: vec![0], data: Vec::new() }
    }

    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} != data len {}",
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshaped(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Leading dimension (batch) and the product of the rest.
    pub fn batch_feat(&self) -> (usize, usize) {
        let b = self.shape.first().copied().unwrap_or(1);
        (b, self.data.len() / b.max(1))
    }

    pub fn dim(&self, i: usize) -> usize {
        self.shape[i]
    }
}

impl ITensor {
    /// Widen to i64.
    pub fn to_i64(&self) -> LTensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&v| v as i64).collect(),
        }
    }

    /// Min/max over the elements (bit-width probes; paper App. E.3).
    pub fn minmax(&self) -> (i32, i32) {
        let mut lo = i32::MAX;
        let mut hi = i32::MIN;
        for &v in &self.data {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if self.data.is_empty() {
            (0, 0)
        } else {
            (lo, hi)
        }
    }

    /// Bits needed to represent every element in two's complement
    /// (`-128` fits in 8 bits). The paper's int16 claim is
    /// `bitwidth() <= 16`.
    pub fn bitwidth(&self) -> u32 {
        self.data
            .iter()
            .map(|&v| {
                let v = v as i64;
                let m = if v < 0 { !v } else { v } as u64;
                64 - m.leading_zeros() + 1
            })
            .max()
            .unwrap_or(1)
    }

    pub fn mean_abs(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|&v| (v as i64).abs() as f64).sum::<f64>()
            / self.data.len() as f64
    }
}

impl LTensor {
    /// Narrow to i32 (values are guaranteed in range by the NITRO analysis;
    /// debug builds assert).
    pub fn to_i32(&self) -> ITensor {
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .map(|&v| {
                    debug_assert!(
                        v >= i32::MIN as i64 && v <= i32::MAX as i64,
                        "int32 overflow: {v}"
                    );
                    v as i32
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_reshape() {
        let t: ITensor = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.len(), 24);
        let t = t.reshaped(&[6, 4]);
        assert_eq!(t.shape, vec![6, 4]);
        assert_eq!(t.batch_feat(), (6, 4));
    }

    #[test]
    #[should_panic]
    fn bad_reshape_panics() {
        let t: ITensor = Tensor::zeros(&[2, 3]);
        let _ = t.reshaped(&[4, 2]);
    }

    #[test]
    fn bitwidth_probe() {
        let t = ITensor::from_vec(&[3], vec![0, 127, -128]);
        assert_eq!(t.bitwidth(), 8); // int8
        let t = ITensor::from_vec(&[1], vec![32767]);
        assert_eq!(t.bitwidth(), 16);
        let t = ITensor::from_vec(&[1], vec![32768]);
        assert_eq!(t.bitwidth(), 17);
    }

    #[test]
    fn minmax_and_meanabs() {
        let t = ITensor::from_vec(&[4], vec![-5, 0, 3, 2]);
        assert_eq!(t.minmax(), (-5, 3));
        assert!((t.mean_abs() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn i64_roundtrip() {
        let t = ITensor::from_vec(&[2], vec![i32::MAX, i32::MIN]);
        assert_eq!(t.to_i64().to_i32(), t);
    }
}
