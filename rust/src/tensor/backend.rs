//! Runtime-dispatched integer kernel backends (scalar / AVX2 / NEON).
//!
//! # One form per op
//!
//! [`KernelBackend`] is the single entry-point surface for the integer
//! kernels. Each op exists in exactly **one** workspace-threaded form on
//! the backend (`matmul_scale`, `conv2d_scale`, `conv2d_weight_grad`,
//! `maxpool2d`, ...): callers pass a [`KernelWorkspace`] and, where the
//! op produces an output tensor on the hot path, a caller-owned `out`
//! whose allocation is reused. The owning conveniences that remain in
//! `ops_int` (`matmul_i64`, `conv2d_i64`, `nitro_relu`, ...) are thin
//! wrappers over this surface — new call sites should either use those
//! wrappers or hold a `KernelBackend` and call it directly; do not grow
//! new `_into`/`_ws` variants in `ops_int`.
//!
//! # ISA selection
//!
//! The active ISA is picked once, on first use:
//!
//! 1. `NITRO_ISA=scalar|avx2|neon` overrides detection. Requesting an
//!    ISA the host cannot run falls back to scalar with a note on
//!    stderr (so a `NITRO_ISA=avx2` CI lane degrades gracefully on an
//!    AVX2-less runner); an unknown value falls back to detection.
//! 2. x86_64 with runtime AVX2 support (`is_x86_feature_detected!`)
//!    selects [`Isa::Avx2`].
//! 3. aarch64 selects [`Isa::Neon`] (NEON is baseline on aarch64).
//! 4. Anything else selects [`Isa::Scalar`].
//!
//! Tests and benches may switch the process-wide ISA with
//! [`set_active`] or pin a local one via [`KernelBackend::with_isa`].
//!
//! # Bit-exactness contract
//!
//! Every ISA produces **byte-identical** outputs for every op — SIMD is
//! a pure speed lever, never a numerics change. That is possible
//! because the kernels are exact-integer:
//!
//! - The chunked-i32 dot products accumulate with *wrapping* i32
//!   addition, which is associative and commutative, so any SIMD lane
//!   order (8-lane AVX2 partial sums, 4-lane NEON, scalar left fold)
//!   yields the same bits. The `safe_chunk` bound guarantees the
//!   partial sums never actually wrap; the wrapping semantics only
//!   make the reordering legal.
//! - The elementwise kernels floor-divide by a positive scale factor.
//!   For integers `n`, `d` with `d >= 1` and `|n| < 2^53`,
//!   `floor(fl(n/d)) == div_floor(n, d)` in f64: an integer quotient is
//!   exactly representable and correctly-rounded division returns it,
//!   while a non-integer quotient sits at least `1/d` from the nearest
//!   integer and the rounding error is below `|n/d| * 2^-53 < 1/d` —
//!   the division cannot cross an integer boundary. The AVX2 element
//!   kernels use this to do 4-lane `cvtepi32_pd / div_pd / floor_pd /
//!   cvtpd_epi32` floor division, guarded so any operand outside the
//!   proven range takes the scalar `div_floor` lane-for-lane.
//!
//! The contract is enforced three ways: per-ISA property tests here and
//! in `ops_int` (including ±`i32::MAX` rails and the i32-overflow
//! fallback boundary), whole-training-run identity tests
//! (`tests/isa.rs`, golden-trace replay), and a hard gate in
//! `nitro bench-kernels` that fails the run on any SIMD-vs-scalar
//! divergence.

use super::ops_int::{self, KernelWorkspace, INT8_MAX};
use super::{ITensor, LTensor};
use crate::util::div_floor;
use std::sync::atomic::{AtomicU8, Ordering};

// ---------------------------------------------------------------------------
// ISA selection
// ---------------------------------------------------------------------------

/// Instruction set the integer kernels dispatch on. All variants exist
/// on every build target (so `NITRO_ISA` parses uniformly); only the
/// [`supported`] ones can become active.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
#[repr(u8)]
pub enum Isa {
    /// Portable scalar loops — the reference every other ISA must match
    /// bit-for-bit.
    Scalar = 1,
    /// x86_64 AVX2: 8-lane i32 dots, vectorized row copies, 4-lane f64
    /// floor-division element kernels.
    Avx2 = 2,
    /// aarch64 NEON: 4-lane i32 dots; element kernels currently take
    /// the scalar path.
    Neon = 3,
}

impl Isa {
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    pub fn parse(s: &str) -> Option<Isa> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Isa::Scalar),
            "avx2" => Some(Isa::Avx2),
            "neon" => Some(Isa::Neon),
            _ => None,
        }
    }

    fn from_u8(v: u8) -> Isa {
        match v {
            2 => Isa::Avx2,
            3 => Isa::Neon,
            _ => Isa::Scalar,
        }
    }
}

/// Can `isa` run on this host (compile target + runtime CPU features)?
pub fn supported(isa: Isa) -> bool {
    match isa {
        Isa::Scalar => true,
        Isa::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            {
                std::arch::is_x86_feature_detected!("avx2")
            }
            #[cfg(not(target_arch = "x86_64"))]
            {
                false
            }
        }
        // NEON is baseline on aarch64 — no runtime probe needed.
        Isa::Neon => cfg!(target_arch = "aarch64"),
    }
}

/// Every ISA this host can run, scalar first (benches iterate this to
/// produce the per-ISA comparison section).
pub fn supported_isas() -> Vec<Isa> {
    [Isa::Scalar, Isa::Avx2, Isa::Neon]
        .into_iter()
        .filter(|&i| supported(i))
        .collect()
}

/// Best ISA for this host: avx2 → neon → scalar.
pub fn detect() -> Isa {
    if supported(Isa::Avx2) {
        Isa::Avx2
    } else if supported(Isa::Neon) {
        Isa::Neon
    } else {
        Isa::Scalar
    }
}

/// Process-wide active ISA; 0 = not yet initialized. A plain atomic
/// (not a `OnceLock`) so [`set_active`] can re-point it — safe because
/// every ISA is bit-identical, so a mid-run switch changes speed only.
static ACTIVE: AtomicU8 = AtomicU8::new(0);

/// The process-wide active ISA, initializing from `NITRO_ISA` /
/// detection on first call.
pub fn active() -> Isa {
    match ACTIVE.load(Ordering::Relaxed) {
        0 => {
            let isa = init_from_env();
            ACTIVE.store(isa as u8, Ordering::Relaxed);
            isa
        }
        v => Isa::from_u8(v),
    }
}

/// Point the process-wide backend at `isa` (must be [`supported`]).
/// Intended for tests and benches; all ISAs are bit-identical, so this
/// never changes results.
pub fn set_active(isa: Isa) {
    assert!(
        supported(isa),
        "ISA {} is not supported on this host",
        isa.name()
    );
    ACTIVE.store(isa as u8, Ordering::Relaxed);
}

fn init_from_env() -> Isa {
    match std::env::var("NITRO_ISA") {
        Ok(s) => match Isa::parse(&s) {
            Some(isa) if supported(isa) => isa,
            Some(isa) => {
                eprintln!(
                    "nitro: NITRO_ISA={} is not supported on this host; \
                     using scalar kernels",
                    isa.name()
                );
                Isa::Scalar
            }
            None => {
                eprintln!(
                    "nitro: unknown NITRO_ISA={s:?} (expected \
                     scalar|avx2|neon); auto-detecting"
                );
                detect()
            }
        },
        Err(_) => detect(),
    }
}

// ---------------------------------------------------------------------------
// KernelBackend — the one-form-per-op entry surface
// ---------------------------------------------------------------------------

/// Integer kernel entry points bound to one ISA. Cheap to copy; grab
/// the process-wide one with [`kernels`] or pin an ISA with
/// [`KernelBackend::with_isa`].
#[derive(Clone, Copy, Debug)]
pub struct KernelBackend {
    isa: Isa,
}

/// The process-wide backend (active ISA).
pub fn kernels() -> KernelBackend {
    KernelBackend { isa: active() }
}

impl KernelBackend {
    /// Backend pinned to `isa` (panics if the host cannot run it —
    /// iterate [`supported_isas`] to stay portable).
    pub fn with_isa(isa: Isa) -> KernelBackend {
        assert!(
            supported(isa),
            "ISA {} is not supported on this host",
            isa.name()
        );
        KernelBackend { isa }
    }

    pub fn isa(self) -> Isa {
        self.isa
    }

    /// `a (m,k) i32 × b (k,n) i32`, **accumulating** into `out` (m,n)
    /// i64 — callers zero it or reuse it to sum over a batch.
    #[allow(clippy::too_many_arguments)]
    pub fn matmul_i64(
        self, a: &[i32], b: &[i32], m: usize, k: usize, n: usize,
        out: &mut [i64], workers: usize,
    ) {
        ops_int::matmul_i64_into(self.isa, a, b, m, k, n, out, workers);
    }

    /// Fused `floor((a × b) / sf)` into a caller-owned tensor; the i64
    /// accumulator lives in `ws`, so a long-lived `out` makes the
    /// steady state allocation-free. `a` is logically 2-D (see
    /// [`ops_int::matmul_i64`]).
    pub fn matmul_scale(
        self, a: &ITensor, b: &ITensor, sf: i64, ws: &mut KernelWorkspace,
        out: &mut ITensor,
    ) {
        ops_int::matmul_scale_into(self.isa, a, b, sf, ws, out);
    }

    /// Integer conv2d `x (B,C,H,W) × w (O,C,K,K) -> (B,O,Ho,Wo)` i64;
    /// leaves the im2col patches of `x` cached in `ws` for a following
    /// [`KernelBackend::conv2d_weight_grad`].
    pub fn conv2d(
        self, x: &ITensor, w: &ITensor, padding: usize,
        ws: &mut KernelWorkspace,
    ) -> LTensor {
        ops_int::conv2d_i64_ws(self.isa, x, w, padding, ws)
    }

    /// Fused `floor(conv2d(x, w) / sf)` into a caller-owned tensor;
    /// patches of `x` stay cached in `ws` for the weight-grad pass.
    #[allow(clippy::too_many_arguments)]
    pub fn conv2d_scale(
        self, x: &ITensor, w: &ITensor, padding: usize, sf: i64,
        ws: &mut KernelWorkspace, out: &mut ITensor,
    ) {
        ops_int::conv2d_scale_into(self.isa, x, w, padding, sf, ws, out);
    }

    /// Conv weight gradient `(O,C,K,K)` i64, reusing the im2col patches
    /// cached in `ws` by the matching forward when the tag matches.
    pub fn conv2d_weight_grad(
        self, x: &ITensor, g: &ITensor, kernel: usize, padding: usize,
        ws: &mut KernelWorkspace,
    ) -> LTensor {
        ops_int::conv2d_weight_grad_ws(self.isa, x, g, kernel, padding, ws)
    }

    /// Max pool without the argmax (inference needs no backward
    /// routing) into a caller-owned tensor. Bit-identical to the
    /// owning [`ops_int::maxpool2d`] — same core loop on every ISA.
    pub fn maxpool2d(
        self, x: &ITensor, size: usize, stride: usize, out: &mut ITensor,
    ) {
        ops_int::maxpool2d_into(x, size, stride, out);
    }

    /// Patch extraction `x (B,C,H,W) -> (B, Ho*Wo, C*K*K)`.
    pub fn im2col(self, x: &ITensor, kernel: usize, padding: usize) -> ITensor {
        ops_int::im2col_isa(self.isa, x, kernel, padding)
    }

    /// NITRO Scaling Layer: `z* = floor(z / sf)`, i64 in → i32 out.
    pub fn nitro_scale(self, z: &LTensor, sf: i64) -> ITensor {
        let mut out = ITensor {
            shape: z.shape.clone(),
            data: vec![0i32; z.data.len()],
        };
        scale_slice(self.isa, &z.data, sf, &mut out.data);
        out
    }

    /// NITRO-ReLU forward over scaled pre-activations.
    pub fn nitro_relu(self, zs: &ITensor, alpha_inv: i64) -> ITensor {
        let mut out = zs.clone();
        self.nitro_relu_inplace(&mut out, alpha_inv);
        out
    }

    /// NITRO-ReLU in place (the serving forward keeps no
    /// pre-activation).
    pub fn nitro_relu_inplace(self, zs: &mut ITensor, alpha_inv: i64) {
        let mu = ops_int::nitro_relu_mu(alpha_inv);
        relu_slice(self.isa, &mut zs.data, alpha_inv, mu);
    }

    /// Fused scale+ReLU: one pass i64 → i32.
    pub fn nitro_scale_relu(
        self, z: &LTensor, sf: i64, alpha_inv: i64,
    ) -> ITensor {
        let mu = ops_int::nitro_relu_mu(alpha_inv);
        let mut out = ITensor {
            shape: z.shape.clone(),
            data: vec![0i32; z.data.len()],
        };
        scale_relu_slice(self.isa, &z.data, sf, alpha_inv, mu, &mut out.data);
        out
    }

    /// NITRO-ReLU backward: exact piecewise derivative.
    pub fn nitro_relu_bwd(
        self, zs: &ITensor, g: &ITensor, alpha_inv: i64,
    ) -> ITensor {
        assert_eq!(zs.shape, g.shape);
        let mut out = ITensor {
            shape: g.shape.clone(),
            data: vec![0i32; g.data.len()],
        };
        relu_bwd_slice(self.isa, &zs.data, &g.data, alpha_inv, &mut out.data);
        out
    }

    /// Clamp every element into the symmetric bitwidth rail `±rail`
    /// (`rail = 2^(b−1)−1`). Callers must skip the call entirely at
    /// full-width rails: clamping to ±i32::MAX still remaps i32::MIN,
    /// so "no rail" means "no call", never "clamp to MAX".
    pub fn clamp_i32(self, t: &mut ITensor, rail: i32) {
        clamp_slice(self.isa, &mut t.data, rail);
    }
}

// ---------------------------------------------------------------------------
// SIMD primitives (dispatched per ISA, bit-identical to scalar)
// ---------------------------------------------------------------------------

/// Largest divisor the f64 floor-division lemma covers (`2^53`);
/// anything at or past it takes the scalar path.
const MAX_F64_DIV: i64 = 1 << 53;

/// Wrapping i32 dot product — the inner kernel of every chunked-i32
/// contraction. The caller (`safe_chunk`) guarantees the true sum fits
/// i32; wrapping arithmetic makes any lane order bit-identical anyway.
#[inline]
pub(crate) fn dot_i32_wrap(isa: Isa, a: &[i32], b: &[i32]) -> i32 {
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { dot_wrap_avx2(a, b) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { dot_wrap_neon(a, b) },
        _ => dot_wrap_scalar(a, b),
    }
}

#[inline]
fn dot_wrap_scalar(a: &[i32], b: &[i32]) -> i32 {
    let mut acc = 0i32;
    for (&x, &y) in a.iter().zip(b) {
        acc = acc.wrapping_add(x.wrapping_mul(y));
    }
    acc
}

/// `dst.copy_from_slice(src)`, vectorized explicitly on AVX2 — the
/// im2col row-copy primitive.
#[inline]
pub(crate) fn copy_i32(isa: Isa, dst: &mut [i32], src: &[i32]) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { copy_avx2(dst, src) },
        _ => dst.copy_from_slice(src),
    }
}

/// `out[i] = div_floor(z[i], sf)` — the NITRO scaling layer on slices.
///
/// A power-of-two `sf` takes the shift path: for two's-complement
/// integers, `v >> k` *is* `div_floor(v, 2^k)` exactly, so the path is
/// bit-identical to the divide and — being one shared scalar loop — is
/// trivially identical on every ISA.
#[inline]
pub(crate) fn scale_slice(isa: Isa, z: &[i64], sf: i64, out: &mut [i32]) {
    debug_assert_eq!(z.len(), out.len());
    if let Some(k) = ops_int::pow2_shift(sf) {
        for (o, &v) in out.iter_mut().zip(z) {
            *o = (v >> k) as i32;
        }
        return;
    }
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 if sf >= 1 && sf < MAX_F64_DIV => unsafe {
            scale_avx2(z, sf, out)
        },
        _ => scale_scalar(z, sf, out),
    }
}

fn scale_scalar(z: &[i64], sf: i64, out: &mut [i32]) {
    for (o, &v) in out.iter_mut().zip(z) {
        *o = div_floor(v, sf) as i32;
    }
}

/// NITRO-ReLU in place on a slice (`mu` pre-computed by the caller).
#[inline]
pub(crate) fn relu_slice(isa: Isa, vs: &mut [i32], alpha_inv: i64, mu: i32) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 if alpha_inv >= 1 && alpha_inv <= i32::MAX as i64 => unsafe {
            relu_avx2(vs, alpha_inv, mu)
        },
        _ => relu_scalar(vs, alpha_inv, mu),
    }
}

fn relu_scalar(vs: &mut [i32], alpha_inv: i64, mu: i32) {
    for v in vs {
        let out = if *v < 0 {
            div_floor((*v).max(-INT8_MAX) as i64, alpha_inv) as i32
        } else {
            (*v).min(INT8_MAX)
        };
        *v = out.wrapping_sub(mu);
    }
}

/// Fused scale+ReLU on slices.
///
/// Power-of-two `sf` takes the shift path (same argument as
/// [`scale_slice`]): `scale_relu_one_shift` is `scale_relu_one` with
/// the floor-divide replaced by an arithmetic shift, shared verbatim
/// across ISAs so the bit-exactness contract holds by construction.
#[inline]
pub(crate) fn scale_relu_slice(
    isa: Isa, z: &[i64], sf: i64, alpha_inv: i64, mu: i32, out: &mut [i32],
) {
    debug_assert_eq!(z.len(), out.len());
    if let Some(k) = ops_int::pow2_shift(sf) {
        for (o, &zv) in out.iter_mut().zip(z) {
            *o = scale_relu_one_shift(zv, k, alpha_inv, mu);
        }
        return;
    }
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2
            if sf >= 1
                && sf < MAX_F64_DIV
                && alpha_inv >= 1
                && alpha_inv <= i32::MAX as i64 =>
        unsafe { scale_relu_avx2(z, sf, alpha_inv, mu, out) },
        _ => scale_relu_scalar(z, sf, alpha_inv, mu, out),
    }
}

#[inline]
fn scale_relu_one(zv: i64, sf: i64, alpha_inv: i64, mu: i32) -> i32 {
    let v = div_floor(zv, sf);
    let out = if v < 0 {
        div_floor(v.max(-(INT8_MAX as i64)), alpha_inv) as i32
    } else {
        v.min(INT8_MAX as i64) as i32
    };
    out.wrapping_sub(mu)
}

/// [`scale_relu_one`] with `sf = 2^k`: identical i64-domain semantics,
/// floor-divide replaced by the exact arithmetic shift.
#[inline]
fn scale_relu_one_shift(zv: i64, k: u32, alpha_inv: i64, mu: i32) -> i32 {
    let v = zv >> k;
    let out = if v < 0 {
        div_floor(v.max(-(INT8_MAX as i64)), alpha_inv) as i32
    } else {
        v.min(INT8_MAX as i64) as i32
    };
    out.wrapping_sub(mu)
}

fn scale_relu_scalar(
    z: &[i64], sf: i64, alpha_inv: i64, mu: i32, out: &mut [i32],
) {
    for (o, &zv) in out.iter_mut().zip(z) {
        *o = scale_relu_one(zv, sf, alpha_inv, mu);
    }
}

/// NITRO-ReLU backward on slices.
#[inline]
pub(crate) fn relu_bwd_slice(
    isa: Isa, zs: &[i32], g: &[i32], alpha_inv: i64, out: &mut [i32],
) {
    debug_assert_eq!(zs.len(), g.len());
    debug_assert_eq!(zs.len(), out.len());
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 if alpha_inv >= 1 && alpha_inv <= i32::MAX as i64 => unsafe {
            relu_bwd_avx2(zs, g, alpha_inv, out)
        },
        _ => relu_bwd_scalar(zs, g, alpha_inv, out),
    }
}

fn relu_bwd_scalar(zs: &[i32], g: &[i32], alpha_inv: i64, out: &mut [i32]) {
    for ((o, &x), &gv) in out.iter_mut().zip(zs).zip(g) {
        *o = if x < -INT8_MAX || x > INT8_MAX {
            0
        } else if x < 0 {
            div_floor(gv as i64, alpha_inv) as i32
        } else {
            gv
        };
    }
}

/// Symmetric bitwidth-rail clamp `v ← clamp(v, −rail, rail)` in place.
/// `rail` must be positive and below `i32::MAX` — full-width rails mean
/// "skip the call", which the callers enforce.
#[inline]
pub(crate) fn clamp_slice(isa: Isa, vs: &mut [i32], rail: i32) {
    assert!(
        rail > 0 && rail < i32::MAX,
        "clamp rail must be in 1..i32::MAX"
    );
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { clamp_avx2(vs, rail) },
        _ => clamp_scalar(vs, rail),
    }
}

fn clamp_scalar(vs: &mut [i32], rail: i32) {
    for v in vs {
        *v = (*v).clamp(-rail, rail);
    }
}

// ---------------------------------------------------------------------------
// AVX2 implementations (x86_64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::*;
    use std::arch::x86_64::*;

    /// 8-lane wrapping i32 dot: `vpmulld` keeps the low 32 bits (=
    /// `wrapping_mul`) and `vpaddd` wraps, so per-lane partial sums
    /// plus a wrapping horizontal fold are bit-identical to the scalar
    /// left fold.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_wrap_avx2(a: &[i32], b: &[i32]) -> i32 {
        unsafe {
            let n = a.len().min(b.len());
            let mut acc = _mm256_setzero_si256();
            let mut i = 0usize;
            while i + 8 <= n {
                let va = _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
                let vb = _mm256_loadu_si256(b.as_ptr().add(i) as *const __m256i);
                acc = _mm256_add_epi32(acc, _mm256_mullo_epi32(va, vb));
                i += 8;
            }
            let mut lanes = [0i32; 8];
            _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
            let mut total = 0i32;
            for l in lanes {
                total = total.wrapping_add(l);
            }
            while i < n {
                total = total.wrapping_add(a[i].wrapping_mul(b[i]));
                i += 1;
            }
            total
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn copy_avx2(dst: &mut [i32], src: &[i32]) {
        unsafe {
            debug_assert_eq!(dst.len(), src.len());
            let n = dst.len();
            let mut i = 0usize;
            while i + 8 <= n {
                let v = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
                _mm256_storeu_si256(dst.as_mut_ptr().add(i) as *mut __m256i, v);
                i += 8;
            }
            dst[i..].copy_from_slice(&src[i..]);
        }
    }

    /// Exact 4-lane `div_floor(v, d)` for i32 lanes and a positive
    /// divisor `d < 2^53` (see the module-doc lemma): convert to f64,
    /// divide, floor, convert back — every step exact or provably on
    /// the correct side of the integer boundary.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn floordiv4(v: __m128i, d: __m256d) -> __m128i {
        unsafe {
            let q = _mm256_floor_pd(_mm256_div_pd(_mm256_cvtepi32_pd(v), d));
            _mm256_cvtpd_epi32(q)
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn scale_avx2(z: &[i64], sf: i64, out: &mut [i32]) {
        unsafe {
            // nitro-lint: allow(no-float) floor-div lemma: exact for |n| < 2^53
            let d = _mm256_set1_pd(sf as f64);
            let n = z.len();
            let mut i = 0usize;
            while i + 4 <= n {
                let q = &z[i..i + 4];
                // The f64 lemma needs |dividend| < 2^53; in-contract
                // accumulator values fit i32 after scaling's input bound,
                // but guard per quad and take the scalar lane otherwise.
                if q.iter().all(|&v| v as i32 as i64 == v) {
                    let v = _mm_set_epi32(
                        q[3] as i32, q[2] as i32, q[1] as i32, q[0] as i32,
                    );
                    let r = floordiv4(v, d);
                    _mm_storeu_si128(out.as_mut_ptr().add(i) as *mut __m128i, r);
                } else {
                    for j in 0..4 {
                        out[i + j] = div_floor(z[i + j], sf) as i32;
                    }
                }
                i += 4;
            }
            while i < n {
                out[i] = div_floor(z[i], sf) as i32;
                i += 1;
            }
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn relu_avx2(vs: &mut [i32], alpha_inv: i64, mu: i32) {
        unsafe {
            // nitro-lint: allow(no-float) floor-div lemma: exact for |n| < 2^53
            let d = _mm256_set1_pd(alpha_inv as f64);
            let lo = _mm_set1_epi32(-INT8_MAX);
            let hi = _mm_set1_epi32(INT8_MAX);
            let muv = _mm_set1_epi32(mu);
            let zero = _mm_setzero_si128();
            let n = vs.len();
            let mut i = 0usize;
            while i + 4 <= n {
                let v = _mm_loadu_si128(vs.as_ptr().add(i) as *const __m128i);
                let isneg = _mm_cmplt_epi32(v, zero);
                // negative branch: div_floor(max(v, -127), alpha_inv);
                // computed for every lane, selected only where v < 0
                let divided = floordiv4(_mm_max_epi32(v, lo), d);
                let pos = _mm_min_epi32(v, hi);
                let sel = _mm_blendv_epi8(pos, divided, isneg);
                let r = _mm_sub_epi32(sel, muv);
                _mm_storeu_si128(vs.as_mut_ptr().add(i) as *mut __m128i, r);
                i += 4;
            }
            relu_scalar(&mut vs[i..], alpha_inv, mu);
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn scale_relu_avx2(
        z: &[i64], sf: i64, alpha_inv: i64, mu: i32, out: &mut [i32],
    ) {
        unsafe {
            // nitro-lint: allow(no-float) floor-div lemma: exact for |n| < 2^53
            let ds = _mm256_set1_pd(sf as f64);
            // nitro-lint: allow(no-float) floor-div lemma: exact for |n| < 2^53
            let da = _mm256_set1_pd(alpha_inv as f64);
            let lo = _mm_set1_epi32(-INT8_MAX);
            let hi = _mm_set1_epi32(INT8_MAX);
            let muv = _mm_set1_epi32(mu);
            let zero = _mm_setzero_si128();
            let n = z.len();
            let mut i = 0usize;
            while i + 4 <= n {
                let q = &z[i..i + 4];
                if q.iter().all(|&v| v as i32 as i64 == v) {
                    let zv = _mm_set_epi32(
                        q[3] as i32, q[2] as i32, q[1] as i32, q[0] as i32,
                    );
                    // |div_floor(z, sf)| <= |z|, so the scaled value stays
                    // in i32 and the fused relu matches the i64 scalar form
                    let v = floordiv4(zv, ds);
                    let isneg = _mm_cmplt_epi32(v, zero);
                    let divided = floordiv4(_mm_max_epi32(v, lo), da);
                    let pos = _mm_min_epi32(v, hi);
                    let sel = _mm_blendv_epi8(pos, divided, isneg);
                    let r = _mm_sub_epi32(sel, muv);
                    _mm_storeu_si128(out.as_mut_ptr().add(i) as *mut __m128i, r);
                } else {
                    for j in 0..4 {
                        out[i + j] = scale_relu_one(z[i + j], sf, alpha_inv, mu);
                    }
                }
                i += 4;
            }
            while i < n {
                out[i] = scale_relu_one(z[i], sf, alpha_inv, mu);
                i += 1;
            }
        }
    }

    /// 8-lane symmetric clamp: `min(max(v, −rail), rail)` — exact, so
    /// bit-identity with the scalar `clamp` is structural.
    #[target_feature(enable = "avx2")]
    pub unsafe fn clamp_avx2(vs: &mut [i32], rail: i32) {
        unsafe {
            let lo = _mm256_set1_epi32(-rail);
            let hi = _mm256_set1_epi32(rail);
            let n = vs.len();
            let mut i = 0usize;
            while i + 8 <= n {
                let v = _mm256_loadu_si256(vs.as_ptr().add(i) as *const __m256i);
                let r = _mm256_min_epi32(_mm256_max_epi32(v, lo), hi);
                _mm256_storeu_si256(vs.as_mut_ptr().add(i) as *mut __m256i, r);
                i += 8;
            }
            clamp_scalar(&mut vs[i..], rail);
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn relu_bwd_avx2(
        zs: &[i32], g: &[i32], alpha_inv: i64, out: &mut [i32],
    ) {
        unsafe {
            // nitro-lint: allow(no-float) floor-div lemma: exact for |n| < 2^53
            let d = _mm256_set1_pd(alpha_inv as f64);
            let lo = _mm_set1_epi32(-INT8_MAX);
            let hi = _mm_set1_epi32(INT8_MAX);
            let zero = _mm_setzero_si128();
            let n = zs.len();
            let mut i = 0usize;
            while i + 4 <= n {
                let x = _mm_loadu_si128(zs.as_ptr().add(i) as *const __m128i);
                let gv = _mm_loadu_si128(g.as_ptr().add(i) as *const __m128i);
                let dead = _mm_or_si128(
                    _mm_cmplt_epi32(x, lo),
                    _mm_cmpgt_epi32(x, hi),
                );
                let isneg = _mm_cmplt_epi32(x, zero);
                let gdiv = floordiv4(gv, d);
                let sel = _mm_blendv_epi8(gv, gdiv, isneg);
                let r = _mm_andnot_si128(dead, sel);
                _mm_storeu_si128(out.as_mut_ptr().add(i) as *mut __m128i, r);
                i += 4;
            }
            relu_bwd_scalar(&zs[i..], &g[i..], alpha_inv, &mut out[i..]);
        }
    }
}

#[cfg(target_arch = "x86_64")]
use avx2::{clamp_avx2, copy_avx2, dot_wrap_avx2, relu_avx2, relu_bwd_avx2,
           scale_avx2, scale_relu_avx2};

// ---------------------------------------------------------------------------
// NEON implementation (aarch64)
// ---------------------------------------------------------------------------

/// 4-lane wrapping i32 dot (`vmlaq_s32` and the horizontal `vaddvq_s32`
/// both use modular arithmetic).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn dot_wrap_neon(a: &[i32], b: &[i32]) -> i32 {
    unsafe {
        use std::arch::aarch64::*;
        let n = a.len().min(b.len());
        let mut acc = vdupq_n_s32(0);
        let mut i = 0usize;
        while i + 4 <= n {
            let va = vld1q_s32(a.as_ptr().add(i));
            let vb = vld1q_s32(b.as_ptr().add(i));
            acc = vmlaq_s32(acc, va, vb);
            i += 4;
        }
        let mut total = vaddvq_s32(acc);
        while i < n {
            total = total.wrapping_add(a[i].wrapping_mul(b[i]));
            i += 1;
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg32;

    #[test]
    fn isa_parse_and_support() {
        assert_eq!(Isa::parse("scalar"), Some(Isa::Scalar));
        assert_eq!(Isa::parse(" AVX2 "), Some(Isa::Avx2));
        assert_eq!(Isa::parse("neon"), Some(Isa::Neon));
        assert_eq!(Isa::parse("sse9"), None);
        assert!(supported(Isa::Scalar));
        let sup = supported_isas();
        assert_eq!(sup[0], Isa::Scalar);
        assert!(sup.contains(&detect()));
        // active() always returns something the host can run
        assert!(supported(active()));
    }

    #[test]
    fn dot_wrap_bitexact_across_isas_prop() {
        prop::check("isa_dot", 40, |g| {
            let n = g.usize_in(0, 70);
            let mut a = g.vec_i32(n, -127, 127);
            let mut b = g.vec_i32(n, -32768, 32767);
            if n >= 2 && g.usize_in(0, 3) == 0 {
                // rail inputs: products overflow i32 and must wrap the
                // same way on every ISA
                a[0] = i32::MAX;
                b[0] = i32::MAX;
                a[1] = i32::MIN;
                b[1] = i32::MAX;
            }
            let want = dot_wrap_scalar(&a, &b);
            for isa in supported_isas() {
                assert_eq!(
                    dot_i32_wrap(isa, &a, &b),
                    want,
                    "isa={} n={n}",
                    isa.name()
                );
            }
        });
    }

    #[test]
    fn copy_bitexact_across_isas() {
        let mut g = Pcg32::new(3);
        for n in [0usize, 1, 7, 8, 9, 31, 64, 100] {
            let src: Vec<i32> =
                (0..n).map(|_| g.range_i32(i32::MIN, i32::MAX)).collect();
            for isa in supported_isas() {
                let mut dst = vec![0i32; n];
                copy_i32(isa, &mut dst, &src);
                assert_eq!(dst, src, "isa={} n={n}", isa.name());
            }
        }
    }

    #[test]
    fn element_kernels_bitexact_across_isas_prop() {
        prop::check("isa_elem", 40, |g| {
            let n = g.usize_in(0, 67);
            // i64_wide mixes magnitudes up to ~2^44: exercises both the
            // 4-lane f64 path (i32-range values) and the per-quad
            // scalar fallback (values past the i32 rail)
            let z = g.vec_i64(n);
            let zi: Vec<i32> = (0..n)
                .map(|_| match g.usize_in(0, 5) {
                    0 => i32::MAX,
                    1 => i32::MIN,
                    _ => g.i32_in(-300, 300),
                })
                .collect();
            let gr = g.vec_i32(n, -2000, 2000);
            let sf = [1i64, 7, 256, 256 * 784, MAX_F64_DIV - 1, MAX_F64_DIV]
                [g.usize_in(0, 5)];
            let ai = [1i64, 2, 10, 100, i32::MAX as i64][g.usize_in(0, 4)];
            let mu = ops_int::nitro_relu_mu(ai);

            let mut want_scale = vec![0i32; n];
            scale_scalar(&z, sf, &mut want_scale);
            let mut want_relu = zi.clone();
            relu_scalar(&mut want_relu, ai, mu);
            let mut want_sr = vec![0i32; n];
            scale_relu_scalar(&z, sf, ai, mu, &mut want_sr);
            let mut want_bwd = vec![0i32; n];
            relu_bwd_scalar(&zi, &gr, ai, &mut want_bwd);

            for isa in supported_isas() {
                let mut got = vec![0i32; n];
                scale_slice(isa, &z, sf, &mut got);
                assert_eq!(got, want_scale, "scale isa={}", isa.name());
                let mut got = zi.clone();
                relu_slice(isa, &mut got, ai, mu);
                assert_eq!(got, want_relu, "relu isa={}", isa.name());
                let mut got = vec![0i32; n];
                scale_relu_slice(isa, &z, sf, ai, mu, &mut got);
                assert_eq!(got, want_sr, "scale_relu isa={}", isa.name());
                let mut got = vec![0i32; n];
                relu_bwd_slice(isa, &zi, &gr, ai, &mut got);
                assert_eq!(got, want_bwd, "relu_bwd isa={}", isa.name());
            }
        });
    }

    #[test]
    fn pow2_shift_path_matches_div_floor_exactly() {
        // every power-of-two sf dispatches to the shift path; it must be
        // indistinguishable from the floor-divide reference, including
        // negatives, zero, and values far past the i32 rail
        prop::check("pow2_shift", 40, |g| {
            let n = g.usize_in(0, 67);
            let z = g.vec_i64(n);
            let k = [0u32, 1, 8, 13, 33, 53, 62][g.usize_in(0, 6)];
            let sf = 1i64 << k;
            let ai = [1i64, 10, 100][g.usize_in(0, 2)];
            let mu = ops_int::nitro_relu_mu(ai);
            let mut want = vec![0i32; n];
            scale_scalar(&z, sf, &mut want);
            let mut want_sr = vec![0i32; n];
            scale_relu_scalar(&z, sf, ai, mu, &mut want_sr);
            for isa in supported_isas() {
                let mut got = vec![0i32; n];
                scale_slice(isa, &z, sf, &mut got);
                assert_eq!(got, want, "shift scale isa={} k={k}", isa.name());
                let mut got = vec![0i32; n];
                scale_relu_slice(isa, &z, sf, ai, mu, &mut got);
                assert_eq!(got, want_sr, "shift scale_relu isa={} k={k}",
                           isa.name());
            }
        });
    }

    #[test]
    fn clamp_slice_bitexact_across_isas_including_exact_rails() {
        // bitwidth rails for b in {8, 16, 24}: outputs never exceed
        // ±(2^(b−1)−1), values landing exactly on the rail pass through
        // unchanged, and every ISA agrees byte-for-byte
        prop::check("isa_clamp", 40, |g| {
            let n = g.usize_in(0, 67);
            let b = [8u32, 16, 24][g.usize_in(0, 2)];
            let rail = (1i32 << (b - 1)) - 1;
            let mut v = g.vec_i32(n, -(1 << 26), 1 << 26);
            if n >= 4 {
                v[0] = rail; // exactly on the rail
                v[1] = -rail;
                v[2] = i32::MAX;
                v[3] = i32::MIN;
            }
            let mut want = v.clone();
            clamp_scalar(&mut want, rail);
            for &x in &want {
                assert!(-rail <= x && x <= rail, "b={b} x={x}");
            }
            if n >= 4 {
                assert_eq!((want[0], want[1]), (rail, -rail));
            }
            for isa in supported_isas() {
                let mut got = v.clone();
                clamp_slice(isa, &mut got, rail);
                assert_eq!(got, want, "clamp isa={} b={b}", isa.name());
            }
        });
    }

    #[test]
    fn backend_tensor_ops_match_ops_int_wrappers() {
        // the owning wrappers in ops_int and the backend methods are
        // the same surface — spot-check the tensor-level plumbing
        let z = LTensor::from_vec(&[1, 6], vec![-1, -255, -256, -257, 255, 256]);
        for isa in supported_isas() {
            let kb = KernelBackend::with_isa(isa);
            assert_eq!(kb.isa(), isa);
            let s = kb.nitro_scale(&z, 256);
            assert_eq!(s.data, vec![-1, -1, -1, -2, 0, 1]);
            let zs = ITensor::from_vec(&[1, 5], vec![-200, -100, -1, 50, 200]);
            let gr = ITensor::from_vec(&[1, 5], vec![1000, 1000, -1000, 7, 7]);
            assert_eq!(kb.nitro_relu_bwd(&zs, &gr, 10).data,
                       vec![0, 100, -100, 7, 0]);
            let r = kb.nitro_relu(&zs, 10);
            let mut ri = zs.clone();
            kb.nitro_relu_inplace(&mut ri, 10);
            assert_eq!(r, ri);
            assert_eq!(kb.nitro_scale_relu(&z, 256, 10),
                       kb.nitro_relu(&kb.nitro_scale(&z, 256), 10));
        }
    }

    #[test]
    fn set_active_round_trips() {
        let before = active();
        for isa in supported_isas() {
            set_active(isa);
            assert_eq!(active(), isa);
            assert_eq!(kernels().isa(), isa);
        }
        set_active(before);
    }
}
