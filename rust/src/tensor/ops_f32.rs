//! f32 tensor ops for the floating-point baselines (FP BP and FP LES —
//! Tables 1 & 2 comparison columns). Same layouts as the integer ops so
//! topologies are shared.

use super::{FTensor, Tensor};
use crate::util::par;

/// a (m,k) × b (k,n) -> (m,n)
pub fn matmul(a: &FTensor, b: &FTensor) -> FTensor {
    let (m, k) = (a.shape[0], a.shape[1]);
    let (kb, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, kb);
    let mut out = vec![0f32; m * n];
    par::for_each_chunk(&mut out, n, par::current_workers(), |i, orow| {
        let arow = &a.data[i * k..(i + 1) * k];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b.data[kk * n..kk * n + n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    });
    Tensor::from_vec(&[m, n], out)
}

/// aᵀ (k,m) × b (k,n) -> (m,n)
pub fn matmul_at_b(a: &FTensor, b: &FTensor) -> FTensor {
    let (k, m) = (a.shape[0], a.shape[1]);
    let n = b.shape[1];
    let mut out = vec![0f32; m * n];
    for kk in 0..k {
        let arow = &a.data[kk * m..(kk + 1) * m];
        let brow = &b.data[kk * n..(kk + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    Tensor::from_vec(&[m, n], out)
}

/// a (m,k) × bᵀ (n,k) -> (m,n)
pub fn matmul_a_bt(a: &FTensor, b: &FTensor) -> FTensor {
    let (m, k) = (a.shape[0], a.shape[1]);
    let n = b.shape[0];
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b.data[j * k..(j + 1) * k];
            let mut acc = 0f32;
            for (&av, &bv) in arow.iter().zip(brow) {
                acc += av * bv;
            }
            out[i * n + j] = acc;
        }
    }
    Tensor::from_vec(&[m, n], out)
}

/// im2col with the shared (c, ki, kj) layout.
pub fn im2col(x: &FTensor, kernel: usize, padding: usize) -> FTensor {
    let (b, c, h, w) = s4(x);
    let (ho, wo) = (h + 2 * padding - kernel + 1, w + 2 * padding - kernel + 1);
    let ckk = c * kernel * kernel;
    let mut out = vec![0f32; b * ho * wo * ckk];
    for bi in 0..b {
        for oy in 0..ho {
            for ox in 0..wo {
                let base = ((bi * ho + oy) * wo + ox) * ckk;
                for ci in 0..c {
                    for ki in 0..kernel {
                        let iy = oy as isize + ki as isize - padding as isize;
                        for kj in 0..kernel {
                            let ix = ox as isize + kj as isize - padding as isize;
                            let v = if iy >= 0 && iy < h as isize && ix >= 0
                                && ix < w as isize
                            {
                                x.data[((bi * c + ci) * h + iy as usize) * w
                                    + ix as usize]
                            } else {
                                0.0
                            };
                            out[base + ci * kernel * kernel + ki * kernel + kj] = v;
                        }
                    }
                }
            }
        }
    }
    Tensor::from_vec(&[b, ho * wo, ckk], out)
}

/// conv2d stride 1: x (B,C,H,W) × w (O,C,K,K) -> (B,O,Ho,Wo)
pub fn conv2d(x: &FTensor, w: &FTensor, padding: usize) -> FTensor {
    let (b, c, h, wd) = s4(x);
    let (o, _, k, _) = s4(w);
    let (ho, wo) = (h + 2 * padding - k + 1, wd + 2 * padding - k + 1);
    let patches = im2col(x, k, padding);
    let p = ho * wo;
    let ckk = c * k * k;
    let mut out = vec![0f32; b * o * p];
    par::for_each_chunk(&mut out, o * p, par::current_workers(), |bi, chunk| {
        let pat = &patches.data[bi * p * ckk..(bi + 1) * p * ckk];
        for oi in 0..o {
            let wrow = &w.data[oi * ckk..(oi + 1) * ckk];
            for pi in 0..p {
                let prow = &pat[pi * ckk..(pi + 1) * ckk];
                let mut acc = 0f32;
                for (&wv, &pv) in wrow.iter().zip(prow) {
                    acc += wv * pv;
                }
                chunk[oi * p + pi] = acc;
            }
        }
    });
    Tensor::from_vec(&[b, o, ho, wo], out)
}

/// Gradient wrt conv input (needed by the FP BP baseline where gradients
/// cross layer boundaries): full correlation with flipped kernels.
pub fn conv2d_input_grad(g: &FTensor, w: &FTensor, padding: usize) -> FTensor {
    let (o, c, k, _) = s4(w);
    // build flipped/transposed weights (C,O,K,K)
    let mut wt = vec![0f32; c * o * k * k];
    for oi in 0..o {
        for ci in 0..c {
            for ki in 0..k {
                for kj in 0..k {
                    wt[((ci * o + oi) * k + (k - 1 - ki)) * k + (k - 1 - kj)] =
                        w.data[((oi * c + ci) * k + ki) * k + kj];
                }
            }
        }
    }
    let wt = Tensor::from_vec(&[c, o, k, k], wt);
    conv2d(g, &wt, k - 1 - padding)
}

/// Gradient wrt conv weights, batch-summed.
pub fn conv2d_weight_grad(x: &FTensor, g: &FTensor, kernel: usize,
                          padding: usize) -> FTensor {
    let (b, c, _, _) = s4(x);
    let (_, o, ho, wo) = s4(g);
    let patches = im2col(x, kernel, padding);
    let p = ho * wo;
    let ckk = c * kernel * kernel;
    let mut out = vec![0f32; o * ckk];
    for bi in 0..b {
        let pat = &patches.data[bi * p * ckk..(bi + 1) * p * ckk];
        for oi in 0..o {
            let gplane = &g.data[(bi * o + oi) * p..(bi * o + oi + 1) * p];
            let grow = &mut out[oi * ckk..(oi + 1) * ckk];
            for (pi, &gv) in gplane.iter().enumerate() {
                if gv == 0.0 {
                    continue;
                }
                let prow = &pat[pi * ckk..(pi + 1) * ckk];
                for (acc, &pv) in grow.iter_mut().zip(prow) {
                    *acc += gv * pv;
                }
            }
        }
    }
    Tensor::from_vec(&[o, c, kernel, kernel], out)
}

/// Max pool 2x2/s2 style with argmax (first max wins, same tie-break).
pub fn maxpool2d(x: &FTensor, size: usize, stride: usize)
                 -> (FTensor, Vec<u32>) {
    let (b, c, h, w) = s4(x);
    let ho = (h - size) / stride + 1;
    let wo = (w - size) / stride + 1;
    let mut out = vec![0f32; b * c * ho * wo];
    let mut arg = vec![0u32; b * c * ho * wo];
    for bc in 0..b * c {
        let plane = &x.data[bc * h * w..(bc + 1) * h * w];
        for oy in 0..ho {
            for ox in 0..wo {
                let mut best = f32::NEG_INFINITY;
                let mut besti = 0u32;
                for ki in 0..size {
                    for kj in 0..size {
                        let v = plane[(oy * stride + ki) * w + ox * stride + kj];
                        if v > best {
                            best = v;
                            besti = (ki * size + kj) as u32;
                        }
                    }
                }
                out[bc * ho * wo + oy * wo + ox] = best;
                arg[bc * ho * wo + oy * wo + ox] = besti;
            }
        }
    }
    (Tensor::from_vec(&[b, c, ho, wo], out), arg)
}

pub fn maxpool2d_bwd(g: &FTensor, arg: &[u32], in_shape: &[usize],
                     size: usize, stride: usize) -> FTensor {
    let (b, c, ho, wo) = s4(g);
    let (h, w) = (in_shape[2], in_shape[3]);
    let mut out = vec![0f32; b * c * h * w];
    for bc in 0..b * c {
        for oy in 0..ho {
            for ox in 0..wo {
                let a = arg[bc * ho * wo + oy * wo + ox] as usize;
                let (ki, kj) = (a / size, a % size);
                out[bc * h * w + (oy * stride + ki) * w + ox * stride + kj] +=
                    g.data[bc * ho * wo + oy * wo + ox];
            }
        }
    }
    Tensor::from_vec(&[b, c, h, w], out)
}

/// LeakyReLU fwd (returns mask-relevant input copy is kept by callers).
pub fn leaky_relu(x: &FTensor, alpha: f32) -> FTensor {
    Tensor {
        shape: x.shape.clone(),
        data: x.data.iter().map(|&v| if v >= 0.0 { v } else { alpha * v }).collect(),
    }
}

pub fn leaky_relu_bwd(x: &FTensor, g: &FTensor, alpha: f32) -> FTensor {
    Tensor {
        shape: g.shape.clone(),
        data: x
            .data
            .iter()
            .zip(&g.data)
            .map(|(&xv, &gv)| if xv >= 0.0 { gv } else { alpha * gv })
            .collect(),
    }
}

/// Softmax + cross-entropy over logits (B, G); labels as class indices.
/// Returns (mean loss, gradient wrt logits — already divided by batch).
pub fn softmax_ce(logits: &FTensor, labels: &[usize]) -> (f32, FTensor) {
    let (b, g) = (logits.shape[0], logits.shape[1]);
    assert_eq!(labels.len(), b);
    let mut grad = vec![0f32; b * g];
    let mut loss = 0f64;
    for i in 0..b {
        let row = &logits.data[i * g..(i + 1) * g];
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        let exps: Vec<f32> = row.iter().map(|&v| (v - m).exp()).collect();
        let z: f32 = exps.iter().sum();
        for j in 0..g {
            let p = exps[j] / z;
            grad[i * g + j] = (p - if j == labels[i] { 1.0 } else { 0.0 })
                / b as f32;
            if j == labels[i] {
                loss -= (p.max(1e-12)).ln() as f64;
            }
        }
    }
    (
        (loss / b as f64) as f32,
        Tensor::from_vec(&[b, g], grad),
    )
}

fn s4(t: &FTensor) -> (usize, usize, usize, usize) {
    assert_eq!(t.shape.len(), 4);
    (t.shape[0], t.shape[1], t.shape[2], t.shape[3])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn randf(rng: &mut Pcg32, shape: &[usize]) -> FTensor {
        let n = shape.iter().product();
        FTensor::from_vec(shape, (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect())
    }

    #[test]
    fn matmul_small_exact() {
        let a = FTensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = FTensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(matmul(&a, &b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn conv_input_grad_is_adjoint() {
        // <conv(x, w), g> == <x, conv_input_grad(g, w)> — the adjoint
        // identity that pins correctness of the transposed conv.
        let mut rng = Pcg32::new(11);
        let x = randf(&mut rng, &[2, 3, 5, 5]);
        let w = randf(&mut rng, &[4, 3, 3, 3]);
        let g = randf(&mut rng, &[2, 4, 5, 5]);
        let y = conv2d(&x, &w, 1);
        let gx = conv2d_input_grad(&g, &w, 1);
        let lhs: f64 = y.data.iter().zip(&g.data).map(|(&a, &b)| (a * b) as f64).sum();
        let rhs: f64 = x.data.iter().zip(&gx.data).map(|(&a, &b)| (a * b) as f64).sum();
        assert!((lhs - rhs).abs() < 1e-2 * lhs.abs().max(1.0), "{lhs} vs {rhs}");
    }

    #[test]
    fn conv_weight_grad_is_adjoint() {
        // <conv(x, w), g> == <w, weight_grad(x, g)>
        let mut rng = Pcg32::new(12);
        let x = randf(&mut rng, &[2, 3, 5, 5]);
        let w = randf(&mut rng, &[4, 3, 3, 3]);
        let g = randf(&mut rng, &[2, 4, 5, 5]);
        let y = conv2d(&x, &w, 1);
        let gw = conv2d_weight_grad(&x, &g, 3, 1);
        let lhs: f64 = y.data.iter().zip(&g.data).map(|(&a, &b)| (a * b) as f64).sum();
        let rhs: f64 = w.data.iter().zip(&gw.data).map(|(&a, &b)| (a * b) as f64).sum();
        assert!((lhs - rhs).abs() < 1e-2 * lhs.abs().max(1.0), "{lhs} vs {rhs}");
    }

    #[test]
    fn softmax_ce_gradient_numerical() {
        let mut rng = Pcg32::new(13);
        let logits = randf(&mut rng, &[3, 5]);
        let labels = vec![0usize, 2, 4];
        let (_, grad) = softmax_ce(&logits, &labels);
        // central differences
        let eps = 1e-3f32;
        for idx in 0..logits.data.len() {
            let mut lp = logits.clone();
            lp.data[idx] += eps;
            let mut lm = logits.clone();
            lm.data[idx] -= eps;
            let (fp, _) = softmax_ce(&lp, &labels);
            let (fm, _) = softmax_ce(&lm, &labels);
            let num = (fp - fm) / (2.0 * eps);
            assert!(
                (num - grad.data[idx]).abs() < 2e-3,
                "idx {idx}: {num} vs {}",
                grad.data[idx]
            );
        }
    }

    #[test]
    fn leaky_relu_roundtrip() {
        let x = FTensor::from_vec(&[1, 4], vec![-2.0, -0.5, 0.0, 3.0]);
        let y = leaky_relu(&x, 0.1);
        assert_eq!(y.data, vec![-0.2, -0.05, 0.0, 3.0]);
        let g = FTensor::from_vec(&[1, 4], vec![1.0, 1.0, 1.0, 1.0]);
        let gx = leaky_relu_bwd(&x, &g, 0.1);
        assert_eq!(gx.data, vec![0.1, 0.1, 1.0, 1.0]);
    }

    #[test]
    fn f32_maxpool_matches_int_tiebreak() {
        let x = FTensor::from_vec(&[1, 1, 2, 2], vec![5.0, 5.0, 5.0, 5.0]);
        let (p, a) = maxpool2d(&x, 2, 2);
        assert_eq!(p.data, vec![5.0]);
        assert_eq!(a, vec![0]);
    }
}
