//! Integer contraction and NITRO elementwise kernels — the NativeEngine
//! hot path. Bit-exact mirror of `python/compile/kernels/ref.py`.
//!
//! Entry points: the owning conveniences here (`matmul_i64`,
//! `conv2d_i64`, `nitro_relu`, ...) plus the one workspace-threaded
//! form per op on [`super::backend::KernelBackend`], which also picks
//! the SIMD ISA (see `tensor::backend` for the dispatch and the
//! bit-exactness contract). The internal kernels take an explicit
//! [`Isa`] so every path is testable against the scalar reference.

use super::backend::{self, Isa};
use super::{ITensor, LTensor, Tensor};
use crate::util::{div_floor, par};
use std::cell::RefCell;

pub const INT8_MAX: i32 = 127;
pub const ONE_HOT_VALUE: i32 = 32;

// ---------------------------------------------------------------------------
// kernel workspace (zero-realloc scratch)
// ---------------------------------------------------------------------------

/// Reusable scratch for the integer kernels: transposed-rhs, im2col-patch
/// and i64-accumulator buffers grow to a high-water mark once and are then
/// reused on every call (zero-realloc steady state).
///
/// A conv forward through `KernelBackend::{conv2d, conv2d_scale}` leaves
/// its im2col patches in the workspace tagged with the input geometry; the
/// matching `KernelBackend::conv2d_weight_grad` call reuses them instead
/// of re-extracting — this removes the second per-step im2col the seed
/// paid in `conv2d_weight_grad`. Release builds key reuse on (shape,
/// kernel, padding) — callers must pass the *same input tensor* between
/// forward and weight-grad (as `nn::block` does); debug builds
/// additionally fingerprint the input data and trap a stale reuse (same
/// geometry, mutated bytes) as a missed `invalidate_patches`.
#[derive(Default)]
pub struct KernelWorkspace {
    /// Transposed rhs for the matmul fast path.
    bt: Vec<i32>,
    /// im2col patches `(B, P, CKK)` plus their validity tag.
    patches: Vec<i32>,
    patches_tag: Option<PatchTag>,
    /// i64 accumulator for the fused contract-then-scale paths.
    acc: Vec<i64>,
}

#[derive(Clone, PartialEq, Eq, Debug)]
struct PatchTag {
    x_shape: Vec<usize>,
    kernel: usize,
    padding: usize,
    plen: usize,
    #[cfg(debug_assertions)]
    fingerprint: (u64, i64),
}

impl PatchTag {
    fn new(x: &ITensor, kernel: usize, padding: usize) -> PatchTag {
        let (b, c, h, w) = shape4(x);
        let (ho, wo) = out_hw(h, w, kernel, padding);
        PatchTag {
            x_shape: x.shape.clone(),
            kernel,
            padding,
            plen: b * ho * wo * c * kernel * kernel,
            #[cfg(debug_assertions)]
            fingerprint: crate::util::checksum_i32(&x.data),
        }
    }
}

impl KernelWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Drop the cached im2col patches (the buffer capacity is kept).
    pub fn invalidate_patches(&mut self) {
        self.patches_tag = None;
    }

    /// Unconditionally extract `im2col(x, kernel, padding)` into `patches`
    /// and tag it — the *producer* side (conv forward). Always re-extracts
    /// because a forward pass sees fresh input data every call even when
    /// the shape is unchanged.
    fn fill_patches(&mut self, isa: Isa, x: &ITensor, kernel: usize,
                    padding: usize) {
        let tag = PatchTag::new(x, kernel, padding);
        let plen = tag.plen;
        let buf = grown(&mut self.patches, plen);
        im2col_into(isa, x, kernel, padding, buf);
        self.patches_tag = Some(tag);
    }

    /// Ensure `patches` hold `im2col(x, kernel, padding)`, reusing the
    /// cached extraction when the tag matches — the *consumer* side
    /// (weight grad, which sees the same input its forward just produced
    /// patches for).
    fn ensure_patches(&mut self, isa: Isa, x: &ITensor, kernel: usize,
                      padding: usize) {
        let tag = PatchTag::new(x, kernel, padding);
        if let Some(cached) = self.patches_tag.as_ref() {
            if *cached == tag {
                return;
            }
            // Same geometry but a different tag can only mean the debug
            // fingerprint moved: the caller mutated the input between the
            // producing forward and this weight grad without calling
            // `invalidate_patches`. Release builds would silently reuse
            // stale patches here — trap it while fingerprints exist.
            debug_assert!(
                !(cached.x_shape == tag.x_shape
                    && cached.kernel == tag.kernel
                    && cached.padding == tag.padding),
                "KernelWorkspace: cached im2col patches match this input's \
                 geometry but not its data — the input was mutated after \
                 the forward pass; call invalidate_patches() before reusing \
                 the workspace"
            );
        }
        self.fill_patches(isa, x, kernel, padding);
    }
}

/// Grow-only view: resizes `buf` up to `n` if needed (never shrinks, so
/// the steady state allocates nothing) and returns the first `n` slots.
fn grown<T: Copy + Default>(buf: &mut Vec<T>, n: usize) -> &mut [T] {
    if buf.len() < n {
        buf.resize(n, T::default());
    }
    &mut buf[..n]
}

thread_local! {
    /// Per-thread scratch backing the workspace-less kernel entry points
    /// (`matmul_i64`, `conv2d_i64`, ...): repeated same-shape calls reuse
    /// the high-water-mark buffers instead of re-allocating per call.
    static SCRATCH: RefCell<KernelWorkspace> =
        RefCell::new(KernelWorkspace::new());
}

// ---------------------------------------------------------------------------
// matmul
// ---------------------------------------------------------------------------

/// Largest |v| in a slice (0 for empty).
#[inline]
fn max_abs(xs: &[i32]) -> i64 {
    xs.iter().map(|&v| (v as i64).abs()).max().unwrap_or(0)
}

/// Pick the i32-safe accumulation chunk length for operands bounded by
/// `max_a`/`max_b`, or `None` if even a single product can overflow i32.
///
/// This is the **perf-critical trick of the integer engine** (EXPERIMENTS.md
/// §Perf): with `chunk * max_a * max_b < 2^31` guaranteed, partial sums can
/// accumulate in i32 — which LLVM autovectorizes (8-lane `vpmulld`/`vpaddd`)
/// — and only chunk boundaries pay the i64 widening. Integer addition is
/// associative, so the result is bit-identical to the naive i64 loop.
#[inline]
fn safe_chunk(max_a: i64, max_b: i64, k: usize) -> Option<usize> {
    let prod = max_a.saturating_mul(max_b);
    if prod == 0 {
        return Some(k.max(1));
    }
    if prod >= i32::MAX as i64 {
        return None;
    }
    Some(((i32::MAX as i64 / prod).max(1) as usize).min(k.max(1)))
}

/// Dot product with i32 chunked accumulation (caller guarantees
/// `chunk * max|a| * max|b| < 2^31`); the inner wrapping dot dispatches
/// on the ISA.
#[inline]
fn dot_chunked(isa: Isa, a: &[i32], b: &[i32], chunk: usize) -> i64 {
    let mut total = 0i64;
    let mut ai = a.chunks(chunk);
    let mut bi = b.chunks(chunk);
    while let (Some(ca), Some(cb)) = (ai.next(), bi.next()) {
        total = total.wrapping_add(backend::dot_i32_wrap(isa, ca, cb) as i64);
    }
    total
}

/// Plain i64 dot (fallback when operands may overflow i32 products).
#[inline]
fn dot_i64(a: &[i32], b: &[i32]) -> i64 {
    let mut acc = 0i64;
    for (&x, &y) in a.iter().zip(b) {
        acc = acc.wrapping_add((x as i64).wrapping_mul(y as i64));
    }
    acc
}

fn transpose_into(b: &[i32], k: usize, n: usize, bt: &mut [i32]) {
    debug_assert_eq!(bt.len(), n * k);
    for kk in 0..k {
        let brow = &b[kk * n..(kk + 1) * n];
        for (j, &v) in brow.iter().enumerate() {
            bt[j * k + kk] = v;
        }
    }
}

/// `a (m,k) i32 × b (k,n) i32 -> (m,n) i64`, i64 accumulation.
///
/// `a` is interpreted **logically 2-D**: a rank-4 conv activation
/// `(B,C,H,W)` contracts as `(B, C·H·W)` without a reshape copy — row-major
/// data is identical, so this is bit-equal to flattening first. The
/// conv→linear block boundary and the head rely on this.
pub fn matmul_i64(a: &ITensor, b: &ITensor) -> LTensor {
    let (m, k) = a.batch_feat();
    let (kb, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, kb, "matmul inner dims {k} vs {kb}");
    let mut out = vec![0i64; m * n];
    matmul_i64_into(backend::active(), &a.data, &b.data, m, k, n, &mut out,
                    par::current_workers());
    Tensor::from_vec(&[m, n], out)
}

/// Fused `floor((a × b) / sf)` into a caller-owned output tensor — the
/// linear / learning-layer / head / serving forward path, exposed as
/// `KernelBackend::matmul_scale`: the i64 contraction accumulates into
/// the workspace buffer, and with a long-lived `out` the steady state
/// allocates nothing. `a` is logically 2-D (see [`matmul_i64`]).
pub(crate) fn matmul_scale_into(isa: Isa, a: &ITensor, b: &ITensor, sf: i64,
                                ws: &mut KernelWorkspace, out: &mut ITensor) {
    let (m, k) = a.batch_feat();
    let (kb, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, kb, "matmul inner dims {k} vs {kb}");
    let KernelWorkspace { bt, acc, .. } = ws;
    let accbuf = grown(acc, m * n);
    accbuf.fill(0);
    matmul_i64_into_buf(isa, &a.data, &b.data, m, k, n, accbuf,
                        par::current_workers(), bt);
    out.shape.clear();
    out.shape.extend_from_slice(&[m, n]);
    out.data.clear();
    out.data.resize(m * n, 0);
    backend::scale_slice(isa, accbuf, sf, &mut out.data);
}

/// Core kernel **accumulating** into a caller buffer (callers zero it or
/// reuse it to sum over a batch); parallel over output row blocks, using
/// a per-thread scratch workspace for the transposed rhs. Exposed as
/// `KernelBackend::matmul_i64`.
pub(crate) fn matmul_i64_into(isa: Isa, a: &[i32], b: &[i32], m: usize,
                              k: usize, n: usize, out: &mut [i64],
                              workers: usize) {
    SCRATCH.with(|ws| {
        matmul_i64_into_buf(isa, a, b, m, k, n, out, workers,
                            &mut ws.borrow_mut().bt);
    });
}

/// Cache-blocking tile sizes for the matmul fast path: a `(MM_JTILE,
/// MM_KTILE)` tile of the transposed rhs (~128 KiB) stays L2-resident
/// across every row of a parallel row block.
const MM_JTILE: usize = 64;
const MM_KTILE: usize = 512;

/// [`matmul_i64_into`] with an explicit transpose scratch buffer.
#[allow(clippy::too_many_arguments)]
fn matmul_i64_into_buf(isa: Isa, a: &[i32], b: &[i32], m: usize, k: usize,
                       n: usize, out: &mut [i64], workers: usize,
                       bt: &mut Vec<i32>) {
    assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    // parallel grain: a few row blocks per worker for load balance
    let rows = m.div_ceil(workers.max(1) * 4).max(1);
    match safe_chunk(max_abs(a), max_abs(b), k) {
        Some(chunk) => {
            // row-dot form over a transposed rhs: both operands stream
            // contiguously, the inner loop runs the ISA's wrapping-i32
            // dot, and k-tiles never exceed the i32-safe accumulation
            // chunk
            let bt = grown(bt, n * k);
            transpose_into(b, k, n, bt);
            let bt: &[i32] = bt;
            let ktile = chunk.min(MM_KTILE);
            par::for_each_chunk(out, rows * n, workers, |blk, orows| {
                mm_block(isa, a, bt, k, n, blk * rows, orows, ktile);
            });
        }
        None => {
            // wide-operand fallback: saxpy in i64
            par::for_each_chunk(out, rows * n, workers, |blk, orows| {
                for (r, orow) in orows.chunks_mut(n).enumerate() {
                    let i = blk * rows + r;
                    let arow = &a[i * k..(i + 1) * k];
                    for (kk, &av) in arow.iter().enumerate() {
                        if av == 0 {
                            continue;
                        }
                        let av = av as i64;
                        let brow = &b[kk * n..kk * n + n];
                        for (o, &bv) in orow.iter_mut().zip(brow) {
                            *o = o.wrapping_add(av.wrapping_mul(bv as i64));
                        }
                    }
                }
            });
        }
    }
}

/// Blocked inner kernel over one row block: k-tiles (bounded by the
/// i32-safe chunk) outermost, then j-tiles, so the `bt` tile is reused
/// across every row. i32 partial sums widen to i64 at tile boundaries —
/// bit-identical to any other order (including the SIMD lane order of
/// `dot_i32_wrap`) because wrapping integer addition is associative and
/// each tile obeys the overflow bound.
#[allow(clippy::too_many_arguments)]
fn mm_block(isa: Isa, a: &[i32], bt: &[i32], k: usize, n: usize, r0: usize,
            orows: &mut [i64], ktile: usize) {
    let rows = orows.len() / n;
    let mut kt = 0usize;
    while kt < k {
        let klen = ktile.min(k - kt);
        for jt in (0..n).step_by(MM_JTILE) {
            let jlen = MM_JTILE.min(n - jt);
            for r in 0..rows {
                let arow = &a[(r0 + r) * k + kt..(r0 + r) * k + kt + klen];
                let orow = &mut orows[r * n + jt..r * n + jt + jlen];
                for (jj, o) in orow.iter_mut().enumerate() {
                    let brow =
                        &bt[(jt + jj) * k + kt..(jt + jj) * k + kt + klen];
                    let d = backend::dot_i32_wrap(isa, arow, brow) as i64;
                    *o = o.wrapping_add(d);
                }
            }
        }
        kt += klen;
    }
}

/// `aᵀ (k,m) × b (k,n) -> (m,n) i64` without materializing the transpose —
/// the learning-layer weight-gradient shape (featᵀ · ∇L). `a` is logically
/// 2-D (see [`matmul_i64`]), so conv activations feed linear-block weight
/// grads without a flatten copy.
pub fn matmul_at_b_i64(a: &ITensor, b: &ITensor) -> LTensor {
    let (k, m) = a.batch_feat();
    let (kb, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, kb);
    let mut out = vec![0i64; m * n];
    for kk in 0..k {
        let arow = &a.data[kk * m..(kk + 1) * m];
        let brow = &b.data[kk * n..(kk + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0 {
                continue;
            }
            let av = av as i64;
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o = o.wrapping_add(av.wrapping_mul(bv as i64));
            }
        }
    }
    Tensor::from_vec(&[m, n], out)
}

/// `a (m,k) × bᵀ (n,k) -> (m,n) i64` — the delta^fw shape (∇L · W_lᵀ).
/// Already in row-dot form; uses the chunked i32 fast path when safe.
/// `a` is logically 2-D (see [`matmul_i64`]).
pub fn matmul_a_bt_i64(a: &ITensor, b: &ITensor) -> LTensor {
    let (m, k) = a.batch_feat();
    let (n, kb) = (b.shape[0], b.shape[1]);
    assert_eq!(k, kb);
    let mut out = vec![0i64; m * n];
    let isa = backend::active();
    let chunk = safe_chunk(max_abs(&a.data), max_abs(&b.data), k);
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &b.data[j * k..(j + 1) * k];
            *o = match chunk {
                Some(c) => dot_chunked(isa, arow, brow, c),
                None => dot_i64(arow, brow),
            };
        }
    }
    Tensor::from_vec(&[m, n], out)
}

// ---------------------------------------------------------------------------
// conv2d (stride 1) via im2col
// ---------------------------------------------------------------------------

/// Patch extraction matching ref.im2col: x (B,C,H,W) -> (B, Ho*Wo, C*K*K)
/// with the (c, ki, kj) row-major patch layout.
pub fn im2col(x: &ITensor, kernel: usize, padding: usize) -> ITensor {
    im2col_isa(backend::active(), x, kernel, padding)
}

/// [`im2col`] with an explicit ISA (`KernelBackend::im2col`).
pub(crate) fn im2col_isa(isa: Isa, x: &ITensor, kernel: usize,
                         padding: usize) -> ITensor {
    let (b, c, h, w) = shape4(x);
    let (ho, wo) = out_hw(h, w, kernel, padding);
    let ckk = c * kernel * kernel;
    let mut out = vec![0i32; b * ho * wo * ckk];
    im2col_into(isa, x, kernel, padding, &mut out);
    Tensor::from_vec(&[b, ho * wo, ckk], out)
}

/// [`im2col`] into a caller buffer (every slot is overwritten); parallel
/// over the batch. The scalar ISA keeps the original per-element loop
/// (the bit-identity reference); SIMD ISAs take the row-copy form.
fn im2col_into(isa: Isa, x: &ITensor, kernel: usize, padding: usize,
               out: &mut [i32]) {
    let (b, c, h, w) = shape4(x);
    let (ho, wo) = out_hw(h, w, kernel, padding);
    let ckk = c * kernel * kernel;
    debug_assert_eq!(out.len(), b * ho * wo * ckk);
    let per_sample = ho * wo * ckk;
    par::for_each_chunk(out, per_sample, par::current_workers(),
        |bi, chunk| {
            let sample = &x.data[bi * c * h * w..(bi + 1) * c * h * w];
            if isa == Isa::Scalar {
                im2col_sample(sample, c, h, w, kernel, padding, ho, wo, chunk);
            } else {
                im2col_sample_rows(isa, sample, c, h, w, kernel, padding,
                                   ho, wo, chunk);
            }
        });
}

#[allow(clippy::too_many_arguments)]
fn im2col_sample(x: &[i32], c: usize, h: usize, w: usize, k: usize,
                 pad: usize, ho: usize, wo: usize, out: &mut [i32]) {
    let ckk = c * k * k;
    for oy in 0..ho {
        for ox in 0..wo {
            let patch = &mut out[(oy * wo + ox) * ckk..(oy * wo + ox + 1) * ckk];
            for ci in 0..c {
                let plane = &x[ci * h * w..(ci + 1) * h * w];
                for ki in 0..k {
                    let iy = oy as isize + ki as isize - pad as isize;
                    for kj in 0..k {
                        let ix = ox as isize + kj as isize - pad as isize;
                        let v = if iy >= 0 && iy < h as isize && ix >= 0
                            && ix < w as isize
                        {
                            plane[iy as usize * w + ix as usize]
                        } else {
                            0
                        };
                        patch[ci * k * k + ki * k + kj] = v;
                    }
                }
            }
        }
    }
}

/// [`im2col_sample`] restructured as per-(ci,ki) row copies: zero the
/// out-of-bounds left/right pad columns, then bulk-copy the in-range
/// `kj` span from the input row through the ISA's vector copy. Copies
/// the exact values the scalar loop writes (property-tested identical).
#[allow(clippy::too_many_arguments)]
fn im2col_sample_rows(isa: Isa, x: &[i32], c: usize, h: usize, w: usize,
                      k: usize, pad: usize, ho: usize, wo: usize,
                      out: &mut [i32]) {
    let ckk = c * k * k;
    for oy in 0..ho {
        for ox in 0..wo {
            let patch = &mut out[(oy * wo + ox) * ckk..(oy * wo + ox + 1) * ckk];
            // in-range kernel columns: kj in [lo, hi) keeps
            // ix = ox + kj - pad inside [0, w)
            let lo = pad.saturating_sub(ox).min(k);
            let hi = (w + pad).saturating_sub(ox).clamp(lo, k);
            for ci in 0..c {
                let plane = &x[ci * h * w..(ci + 1) * h * w];
                for ki in 0..k {
                    let row = &mut patch[ci * k * k + ki * k
                        ..ci * k * k + ki * k + k];
                    let iy = oy as isize + ki as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        row.fill(0);
                        continue;
                    }
                    row[..lo].fill(0);
                    if hi > lo {
                        let src = iy as usize * w + (ox + lo - pad);
                        backend::copy_i32(isa, &mut row[lo..hi],
                                          &plane[src..src + (hi - lo)]);
                    }
                    row[hi..].fill(0);
                }
            }
        }
    }
}

/// Integer conv2d: x (B,C,H,W) × w (O,C,K,K) -> (B,O,Ho,Wo) i64. Routed
/// through a per-thread scratch workspace (patch buffer reused across
/// calls).
pub fn conv2d_i64(x: &ITensor, w: &ITensor, padding: usize) -> LTensor {
    SCRATCH.with(|ws| {
        conv2d_i64_ws(backend::active(), x, w, padding, &mut ws.borrow_mut())
    })
}

/// [`conv2d_i64`] with an explicit workspace (`KernelBackend::conv2d`);
/// leaves the im2col patches of `x` cached in `ws` for a following
/// weight-grad call.
pub(crate) fn conv2d_i64_ws(isa: Isa, x: &ITensor, w: &ITensor,
                            padding: usize, ws: &mut KernelWorkspace)
                            -> LTensor {
    let (b, c, h, wd) = shape4(x);
    let (o, cw, k, _) = shape4(w);
    assert_eq!(c, cw, "conv channel mismatch");
    let (ho, wo) = out_hw(h, wd, k, padding);
    let p = ho * wo;
    let ckk = c * k * k;
    ws.fill_patches(isa, x, k, padding);
    let mut out = vec![0i64; b * o * p];
    conv_contract(isa, &ws.patches[..b * p * ckk], &w.data, o, p, ckk,
                  &mut out);
    Tensor::from_vec(&[b, o, ho, wo], out)
}

/// Fused `floor(conv2d(x, w) / sf)` into a caller-owned output tensor
/// (`KernelBackend::conv2d_scale`): the i64 pre-activations live in the
/// workspace accumulator and the im2col patches of `x` stay cached in
/// `ws` for the weight-grad pass.
pub(crate) fn conv2d_scale_into(isa: Isa, x: &ITensor, w: &ITensor,
                                padding: usize, sf: i64,
                                ws: &mut KernelWorkspace, out: &mut ITensor) {
    let (b, c, h, wd) = shape4(x);
    let (o, cw, k, _) = shape4(w);
    assert_eq!(c, cw, "conv channel mismatch");
    let (ho, wo) = out_hw(h, wd, k, padding);
    let p = ho * wo;
    let ckk = c * k * k;
    ws.fill_patches(isa, x, k, padding);
    let KernelWorkspace { patches, acc, .. } = ws;
    let accbuf = grown(acc, b * o * p);
    conv_contract(isa, &patches[..b * p * ckk], &w.data, o, p, ckk, accbuf);
    out.shape.clear();
    out.shape.extend_from_slice(&[b, o, ho, wo]);
    out.data.clear();
    out.data.resize(b * o * p, 0);
    backend::scale_slice(isa, accbuf, sf, &mut out.data);
}

/// Shared conv contraction: out[bi][oi*p + pi] = Σ_ckk w[oi,·]·pat[bi,pi,·]
/// (every slot assigned); parallel over the batch.
fn conv_contract(isa: Isa, patches: &[i32], w: &[i32], o: usize, p: usize,
                 ckk: usize, out: &mut [i64]) {
    let per_sample = o * p;
    let kchunk = safe_chunk(max_abs(w), max_abs(patches), ckk);
    par::for_each_chunk(out, per_sample, par::current_workers(),
        |bi, chunk| {
            let pat = &patches[bi * p * ckk..(bi + 1) * p * ckk];
            for oi in 0..o {
                let wrow = &w[oi * ckk..(oi + 1) * ckk];
                let orow = &mut chunk[oi * p..(oi + 1) * p];
                for (pi, ov) in orow.iter_mut().enumerate() {
                    let prow = &pat[pi * ckk..(pi + 1) * ckk];
                    *ov = match kchunk {
                        Some(c) => dot_chunked(isa, wrow, prow, c),
                        None => dot_i64(wrow, prow),
                    };
                }
            }
        });
}

/// Weight gradient: gw[o, ckk] = Σ_{b,p} g[b,o,p] · patches[b,p,ckk],
/// batch-summed. g: (B,O,Ho,Wo) i32 -> (O,C,K,K) i64.
pub fn conv2d_weight_grad(x: &ITensor, g: &ITensor, kernel: usize,
                          padding: usize) -> LTensor {
    SCRATCH.with(|ws| {
        let ws = &mut *ws.borrow_mut();
        // the thread-local scratch has no producer/consumer contract with
        // this caller — never trust whatever patches are cached there
        ws.invalidate_patches();
        conv2d_weight_grad_ws(backend::active(), x, g, kernel, padding, ws)
    })
}

/// [`conv2d_weight_grad`] with an explicit workspace
/// (`KernelBackend::conv2d_weight_grad`): when `ws` already holds the
/// im2col patches of `x` (left there by the forward pass), the seed's
/// duplicate per-step extraction is skipped entirely.
pub(crate) fn conv2d_weight_grad_ws(isa: Isa, x: &ITensor, g: &ITensor,
                                    kernel: usize, padding: usize,
                                    ws: &mut KernelWorkspace) -> LTensor {
    let (b, c, h, w) = shape4(x);
    let (gb, o, ho, wo) = shape4(g);
    assert_eq!(b, gb);
    debug_assert_eq!(out_hw(h, w, kernel, padding), (ho, wo));
    let p = ho * wo;
    let ckk = c * kernel * kernel;
    ws.ensure_patches(isa, x, kernel, padding);
    let KernelWorkspace { patches, bt, .. } = ws;
    let mut out = vec![0i64; o * ckk];
    // gw (O, CKK) = Σ_b  g_b (O, P) · patches_b (P, CKK): one accumulating
    // matmul per sample — rides the chunked-i32 fast path of the matmul
    // core, with the transpose scratch shared across samples.
    for bi in 0..b {
        let gplane = &g.data[bi * o * p..(bi + 1) * o * p];
        let pat = &patches[bi * p * ckk..(bi + 1) * p * ckk];
        matmul_i64_into_buf(isa, gplane, pat, o, p, ckk, &mut out, 1, bt);
    }
    Tensor::from_vec(&[o, c, kernel, kernel], out)
}

// ---------------------------------------------------------------------------
// max pooling
// ---------------------------------------------------------------------------

/// Shared windowed-max core: first-max-wins over (ki,kj) row-major —
/// the tie-break shared with ref.maxpool2d. `arg`, when provided, must
/// be `out.len()` long and receives the winning in-window index.
fn maxpool2d_core(x: &ITensor, size: usize, stride: usize,
                  out: &mut [i32], mut arg: Option<&mut [i32]>) {
    let (b, c, h, w) = shape4(x);
    let ho = (h - size) / stride + 1;
    let wo = (w - size) / stride + 1;
    debug_assert_eq!(out.len(), b * c * ho * wo);
    for bc in 0..b * c {
        let plane = &x.data[bc * h * w..(bc + 1) * h * w];
        let obase = bc * ho * wo;
        for oy in 0..ho {
            for ox in 0..wo {
                let mut best = i32::MIN;
                let mut besti = 0i32;
                for ki in 0..size {
                    for kj in 0..size {
                        let v = plane[(oy * stride + ki) * w + ox * stride + kj];
                        if v > best {
                            best = v;
                            besti = (ki * size + kj) as i32;
                        }
                    }
                }
                out[obase + oy * wo + ox] = best;
                if let Some(a) = &mut arg {
                    a[obase + oy * wo + ox] = besti;
                }
            }
        }
    }
}

/// Max pool (size, stride) with first-max-wins argmax over (ki,kj)
/// row-major.
pub fn maxpool2d(x: &ITensor, size: usize, stride: usize)
                 -> (ITensor, ITensor) {
    let (b, c, h, w) = shape4(x);
    let ho = (h - size) / stride + 1;
    let wo = (w - size) / stride + 1;
    let mut out = vec![0i32; b * c * ho * wo];
    let mut arg = vec![0i32; b * c * ho * wo];
    maxpool2d_core(x, size, stride, &mut out, Some(&mut arg));
    (
        Tensor::from_vec(&[b, c, ho, wo], out),
        Tensor::from_vec(&[b, c, ho, wo], arg),
    )
}

/// Max pool without the argmax (inference needs no backward routing),
/// written into a caller-owned output tensor
/// (`KernelBackend::maxpool2d`). Values are bit-identical to
/// [`maxpool2d`]'s pooled output — same core loop on every ISA.
pub(crate) fn maxpool2d_into(x: &ITensor, size: usize, stride: usize,
                             out: &mut ITensor) {
    let (b, c, h, w) = shape4(x);
    let ho = (h - size) / stride + 1;
    let wo = (w - size) / stride + 1;
    out.shape.clear();
    out.shape.extend_from_slice(&[b, c, ho, wo]);
    out.data.clear();
    out.data.resize(b * c * ho * wo, 0);
    maxpool2d_core(x, size, stride, &mut out.data, None);
}

/// Scatter gradient to argmax positions.
pub fn maxpool2d_bwd(g: &ITensor, arg: &ITensor, in_shape: &[usize],
                     size: usize, stride: usize) -> ITensor {
    let (b, c, ho, wo) = shape4(g);
    let (hb, hc, h, w) = (in_shape[0], in_shape[1], in_shape[2], in_shape[3]);
    assert_eq!((b, c), (hb, hc));
    let mut out = vec![0i32; b * c * h * w];
    for bc in 0..b * c {
        let obase = bc * ho * wo;
        let plane = &mut out[bc * h * w..(bc + 1) * h * w];
        for oy in 0..ho {
            for ox in 0..wo {
                let a = arg.data[obase + oy * wo + ox] as usize;
                let (ki, kj) = (a / size, a % size);
                plane[(oy * stride + ki) * w + ox * stride + kj] +=
                    g.data[obase + oy * wo + ox];
            }
        }
    }
    Tensor::from_vec(&[b, c, h, w], out)
}

// ---------------------------------------------------------------------------
// NITRO elementwise (paper §3.2)
// ---------------------------------------------------------------------------

/// Checked NITRO scale factor 2^8 · fan_in, clamped to ≥ 1 so a
/// degenerate zero fan-in can never produce a divide-by-zero factor.
/// Overflow is a typed error — wrapping would silently hand the scaling
/// layer a garbage (possibly negative) divisor.
pub fn try_scale_factor_linear(fan_in: usize) -> Result<i64, String> {
    let f = i64::try_from(fan_in)
        .map_err(|_| format!("scale factor overflow: fan_in={fan_in}"))?;
    256i64
        .checked_mul(f)
        .map(|sf| sf.max(1))
        .ok_or_else(|| format!("scale factor overflow: fan_in={fan_in}"))
}

/// Checked NITRO scale factor 2^8 · K² · C_in (see
/// [`try_scale_factor_linear`] for the clamp/overflow contract).
pub fn try_scale_factor_conv(
    kernel: usize, in_channels: usize,
) -> Result<i64, String> {
    let err = || {
        format!(
            "scale factor overflow: kernel={kernel} in_channels={in_channels}"
        )
    };
    let kk = kernel.checked_mul(kernel).ok_or_else(err)?;
    let fan_in = kk.checked_mul(in_channels).ok_or_else(err)?;
    try_scale_factor_linear(fan_in).map_err(|_| err())
}

pub fn scale_factor_linear(fan_in: usize) -> i64 {
    match try_scale_factor_linear(fan_in) {
        Ok(sf) => sf,
        Err(e) => panic!("{e}"),
    }
}

pub fn scale_factor_conv(kernel: usize, in_channels: usize) -> i64 {
    match try_scale_factor_conv(kernel, in_channels) {
        Ok(sf) => sf,
        Err(e) => panic!("{e}"),
    }
}

/// `Some(k)` iff `sf == 2^k`: the shift-rescaling fast path key. For
/// two's-complement integers `v >> k` is exactly `div_floor(v, 2^k)`,
/// so shift-path outputs are bit-identical to the divide on every ISA.
pub fn pow2_shift(sf: i64) -> Option<u32> {
    if sf > 0 && (sf as u64).is_power_of_two() {
        Some(sf.trailing_zeros())
    } else {
        None
    }
}

/// NITRO Scaling Layer: z* = floor(z / SF). i64 in, i32 out.
pub fn nitro_scale(z: &LTensor, sf: i64) -> ITensor {
    backend::kernels().nitro_scale(z, sf)
}

/// Pre-computed NITRO-ReLU mean (paper §3.2). Mirrors ref.nitro_relu_mu.
pub fn nitro_relu_mu(alpha_inv: i64) -> i32 {
    let mu0 = div_floor(-(INT8_MAX as i64), alpha_inv);
    let mu1 = div_floor(-(INT8_MAX as i64), alpha_inv.wrapping_mul(2));
    let mu2 = 63i64;
    let mu3 = INT8_MAX as i64;
    div_floor(
        mu0.wrapping_add(mu1).wrapping_add(mu2).wrapping_add(mu3),
        4,
    ) as i32
}

/// NITRO-ReLU forward over scaled pre-activations.
pub fn nitro_relu(zs: &ITensor, alpha_inv: i64) -> ITensor {
    backend::kernels().nitro_relu(zs, alpha_inv)
}

/// NITRO-ReLU applied in place (the serving forward keeps no
/// pre-activation — no backward pass will need it). Bit-identical to
/// [`nitro_relu`].
pub fn nitro_relu_inplace(zs: &mut ITensor, alpha_inv: i64) {
    backend::kernels().nitro_relu_inplace(zs, alpha_inv);
}

/// Fused scale+ReLU: one pass i64 -> i32 (the NativeEngine analogue of the
/// Pallas `nitro_scale_relu` epilogue kernel).
pub fn nitro_scale_relu(z: &LTensor, sf: i64, alpha_inv: i64) -> ITensor {
    backend::kernels().nitro_scale_relu(z, sf, alpha_inv)
}

/// NITRO-ReLU backward: exact piecewise derivative (DESIGN.md interp. #2).
/// `zs` is the scaled pre-activation that was fed forward.
pub fn nitro_relu_bwd(zs: &ITensor, g: &ITensor, alpha_inv: i64) -> ITensor {
    backend::kernels().nitro_relu_bwd(zs, g, alpha_inv)
}

// ---------------------------------------------------------------------------
// loss / labels (paper §3.3, App. B.2)
// ---------------------------------------------------------------------------

/// One-hot with value 32.
pub fn one_hot32(labels: &[usize], num_classes: usize) -> ITensor {
    let mut out = vec![0i32; labels.len() * num_classes];
    for (i, &y) in labels.iter().enumerate() {
        out[i * num_classes + y] = ONE_HOT_VALUE;
    }
    Tensor::from_vec(&[labels.len(), num_classes], out)
}

/// RSS loss sum + gradient (ŷ − y). The loss accumulator saturates instead
/// of wrapping so a diverging run (App. E.1 "(unstable)") reports a huge
/// positive loss for the trainer's divergence guard rather than a garbage
/// negative number; in-contract values never approach the rail, so this is
/// bit-identical to the JAX reference on all golden traces.
pub fn rss_loss_grad(yhat: &ITensor, y32: &ITensor) -> (i64, ITensor) {
    let (raw, grad) = rss_loss_grad_raw(yhat, y32);
    (raw / 2, grad)
}

/// [`rss_loss_grad`] with the loss **un-halved**: `Σ(ŷ−y)²`. The
/// data-parallel replica path (`train::replica`) reduces these raw
/// per-shard sums across replicas and halves once after the reduction —
/// halving per shard first would lose the odd bits
/// (`⌊a/2⌋ + ⌊b/2⌋ ≠ ⌊(a+b)/2⌋`) and break the bit-identity of replicated
/// losses with single-replica training.
pub fn rss_loss_grad_raw(yhat: &ITensor, y32: &ITensor) -> (i64, ITensor) {
    assert_eq!(yhat.shape, y32.shape);
    let mut loss = 0i64;
    let grad: Vec<i32> = yhat
        .data
        .iter()
        .zip(&y32.data)
        .map(|(&a, &b)| {
            let d = (a as i64).wrapping_sub(b as i64);
            loss = loss.saturating_add(d.saturating_mul(d));
            d as i32
        })
        .collect();
    (loss, Tensor { shape: yhat.shape.clone(), data: grad })
}

fn shape4<T>(t: &Tensor<T>) -> (usize, usize, usize, usize) {
    assert_eq!(t.shape.len(), 4, "expected rank-4, got {:?}", t.shape);
    (t.shape[0], t.shape[1], t.shape[2], t.shape[3])
}

fn out_hw(h: usize, w: usize, k: usize, pad: usize) -> (usize, usize) {
    (h + 2 * pad - k + 1, w + 2 * pad - k + 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::backend::{kernels, supported_isas, KernelBackend};
    use crate::util::prop;
    use crate::util::rng::Pcg32;

    fn rand_it(rng: &mut Pcg32, shape: &[usize], lo: i32, hi: i32) -> ITensor {
        let n = shape.iter().product();
        ITensor::from_vec(shape, (0..n).map(|_| rng.range_i32(lo, hi)).collect())
    }

    // Test-local shims for the consolidated backend surface, so the
    // assertions below read like the op they exercise.
    fn matmul_scale_ws(a: &ITensor, b: &ITensor, sf: i64,
                       ws: &mut KernelWorkspace) -> ITensor {
        let mut out = ITensor::empty();
        kernels().matmul_scale(a, b, sf, ws, &mut out);
        out
    }

    fn conv2d_i64_kb(x: &ITensor, w: &ITensor, padding: usize,
                     ws: &mut KernelWorkspace) -> LTensor {
        kernels().conv2d(x, w, padding, ws)
    }

    fn conv2d_scale_ws(x: &ITensor, w: &ITensor, padding: usize, sf: i64,
                       ws: &mut KernelWorkspace) -> ITensor {
        let mut out = ITensor::empty();
        kernels().conv2d_scale(x, w, padding, sf, ws, &mut out);
        out
    }

    fn conv2d_weight_grad_kb(x: &ITensor, g: &ITensor, kernel: usize,
                             padding: usize, ws: &mut KernelWorkspace)
                             -> LTensor {
        kernels().conv2d_weight_grad(x, g, kernel, padding, ws)
    }

    /// O(n^3) scalar reference matmul for cross-checking the blocked kernel.
    fn matmul_naive(a: &ITensor, b: &ITensor) -> LTensor {
        let (m, k) = (a.shape[0], a.shape[1]);
        let n = b.shape[1];
        let mut out = vec![0i64; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0i64;
                for kk in 0..k {
                    acc += a.data[i * k + kk] as i64 * b.data[kk * n + j] as i64;
                }
                out[i * n + j] = acc;
            }
        }
        LTensor::from_vec(&[m, n], out)
    }

    #[test]
    fn matmul_blocked_equals_naive_prop() {
        prop::check("matmul", 30, |g| {
            let m = g.usize_in(1, 17);
            let k = g.usize_in(1, 40);
            let n = g.usize_in(1, 19);
            let a = ITensor::from_vec(&[m, k], g.vec_i32(m * k, -127, 127));
            let b = ITensor::from_vec(&[k, n], g.vec_i32(k * n, -32768, 32767));
            assert_eq!(matmul_i64(&a, &b), matmul_naive(&a, &b));
        });
    }

    #[test]
    fn matmul_transposed_variants() {
        prop::check("matmul_t", 20, |g| {
            let m = g.usize_in(1, 9);
            let k = g.usize_in(1, 12);
            let n = g.usize_in(1, 7);
            let a = ITensor::from_vec(&[m, k], g.vec_i32(m * k, -100, 100));
            let b = ITensor::from_vec(&[k, n], g.vec_i32(k * n, -100, 100));
            // at_b: build explicit aᵀ then plain matmul
            let mut at = vec![0i32; k * m];
            for i in 0..m {
                for kk in 0..k {
                    at[kk * m + i] = a.data[i * k + kk];
                }
            }
            let at = ITensor::from_vec(&[k, m], at);
            assert_eq!(matmul_at_b_i64(&at, &b), matmul_i64(&a, &b));
            // a_bt: build explicit bᵀ
            let mut bt = vec![0i32; n * k];
            for kk in 0..k {
                for j in 0..n {
                    bt[j * k + kk] = b.data[kk * n + j];
                }
            }
            let bt = ITensor::from_vec(&[n, k], bt);
            assert_eq!(matmul_a_bt_i64(&a, &bt), matmul_i64(&a, &b));
        });
    }

    #[test]
    fn safe_chunk_i32_overflow_boundary() {
        let m = i32::MAX as i64; // 2147483647
        // zero operands: nothing can overflow, chunk covers the whole k
        assert_eq!(safe_chunk(0, 0, 17), Some(17));
        assert_eq!(safe_chunk(0, 123, 0), Some(1), "k clamped to >= 1");
        // a single product at or past the i32 rail: no safe chunk exists
        assert_eq!(safe_chunk(m, 1, 16), None);
        assert_eq!(safe_chunk(1, m, 16), None);
        // exactly one below the rail: chunk 1 is still safe (code uses
        // `prod >= i32::MAX`, so prod == MAX - 1 admits chunk 1)
        assert_eq!(safe_chunk(m - 1, 1, 16), Some(1));
        // 46341^2 just overflows i32, 46340^2 just fits
        assert_eq!(safe_chunk(46341, 46341, 64), None);
        assert_eq!(safe_chunk(46340, 46340, 64), Some(1));
        // int8 x int8: MAX / 16129 products fit an i32 partial sum
        let chunk = safe_chunk(127, 127, 1 << 20).unwrap();
        assert_eq!(chunk, (m / (127 * 127)) as usize);
        assert!((chunk as i64) * 127 * 127 < m, "chunk sum must fit i32");
        assert!((chunk as i64 + 1) * 127 * 127 >= m, "chunk is maximal");
        // chunk never exceeds k
        assert_eq!(safe_chunk(127, 127, 8), Some(8));
    }

    #[test]
    fn dot_chunked_exact_at_chunk_rail() {
        // accumulate 127*127 products right up to the largest safe chunk:
        // the i32 partial sums must not wrap and must equal the i64 dot
        let chunk = safe_chunk(127, 127, 1 << 20).unwrap();
        let n = chunk * 3 + 7; // several full chunks + a ragged tail
        let a = vec![127i32; n];
        let b = vec![-127i32; n];
        for isa in supported_isas() {
            assert_eq!(dot_chunked(isa, &a, &b, chunk), dot_i64(&a, &b),
                       "isa={}", isa.name());
            assert_eq!(dot_chunked(isa, &a, &b, chunk),
                       -(127i64 * 127 * n as i64));
        }
    }

    #[test]
    fn matmul_extreme_magnitudes_take_i64_path() {
        // operands at the i32 rails force safe_chunk -> None; the wide
        // fallback must stay exact
        let m = i32::MAX;
        let a = ITensor::from_vec(&[1, 3], vec![m, -m, m]);
        let b = ITensor::from_vec(&[3, 1], vec![m, m, -m]);
        let z = matmul_i64(&a, &b);
        let mm = m as i64 * m as i64;
        assert_eq!(z.data[0], mm - mm - mm);
    }

    #[test]
    fn matmul_i64_needed_no_wrap() {
        let a = ITensor::from_vec(&[1, 1024], vec![127; 1024]);
        let b = ITensor::from_vec(&[1024, 1], vec![32767; 1024]);
        let z = matmul_i64(&a, &b);
        assert_eq!(z.data[0], 127i64 * 32767 * 1024);
        assert!(z.data[0] > i32::MAX as i64);
    }

    #[test]
    fn conv_identity_kernel() {
        let x = ITensor::from_vec(
            &[1, 1, 4, 4],
            (0..16).map(|v| v - 8).collect(),
        );
        let mut w = vec![0i32; 9];
        w[4] = 1; // center tap
        let w = ITensor::from_vec(&[1, 1, 3, 3], w);
        let z = conv2d_i64(&x, &w, 1);
        assert_eq!(z.shape, vec![1, 1, 4, 4]);
        assert_eq!(z.data, x.data.iter().map(|&v| v as i64).collect::<Vec<_>>());
    }

    #[test]
    fn conv_against_direct_loops_prop() {
        prop::check("conv", 15, |g| {
            let b = g.usize_in(1, 3);
            let c = g.usize_in(1, 4);
            let o = g.usize_in(1, 5);
            let h = g.usize_in(3, 9);
            let w = g.usize_in(3, 9);
            let x = ITensor::from_vec(&[b, c, h, w],
                                      g.vec_i32(b * c * h * w, -127, 127));
            let wt = ITensor::from_vec(&[o, c, 3, 3],
                                       g.vec_i32(o * c * 9, -500, 500));
            let got = conv2d_i64(&x, &wt, 1);
            // direct 7-deep loop reference
            for bi in 0..b {
                for oi in 0..o {
                    for oy in 0..h {
                        for ox in 0..w {
                            let mut acc = 0i64;
                            for ci in 0..c {
                                for ki in 0..3usize {
                                    for kj in 0..3usize {
                                        let iy = oy as isize + ki as isize - 1;
                                        let ix = ox as isize + kj as isize - 1;
                                        if iy < 0 || iy >= h as isize || ix < 0
                                            || ix >= w as isize
                                        {
                                            continue;
                                        }
                                        let xv = x.data[((bi * c + ci) * h
                                            + iy as usize)
                                            * w
                                            + ix as usize]
                                            as i64;
                                        let wv = wt.data[((oi * c + ci) * 3 + ki)
                                            * 3
                                            + kj]
                                            as i64;
                                        acc += xv * wv;
                                    }
                                }
                            }
                            assert_eq!(
                                got.data[((bi * o + oi) * h + oy) * w + ox],
                                acc
                            );
                        }
                    }
                }
            }
        });
    }

    #[test]
    fn weight_grad_matches_finite_structure() {
        // gw[o,c,ki,kj] = Σ_{b,oy,ox} g[b,o,oy,ox] * x[b,c,oy+ki-1,ox+kj-1]
        prop::check("wgrad", 10, |gen| {
            let (b, c, o, h, w) = (2, 2, 3, 5, 4);
            let x = ITensor::from_vec(&[b, c, h, w],
                                      gen.vec_i32(b * c * h * w, -50, 50));
            let g = ITensor::from_vec(&[b, o, h, w],
                                      gen.vec_i32(b * o * h * w, -20, 20));
            let gw = conv2d_weight_grad(&x, &g, 3, 1);
            for oi in 0..o {
                for ci in 0..c {
                    for ki in 0..3usize {
                        for kj in 0..3usize {
                            let mut acc = 0i64;
                            for bi in 0..b {
                                for oy in 0..h {
                                    for ox in 0..w {
                                        let iy = oy as isize + ki as isize - 1;
                                        let ix = ox as isize + kj as isize - 1;
                                        if iy < 0 || iy >= h as isize || ix < 0
                                            || ix >= w as isize
                                        {
                                            continue;
                                        }
                                        acc += g.data
                                            [((bi * o + oi) * h + oy) * w + ox]
                                            as i64
                                            * x.data[((bi * c + ci) * h
                                                + iy as usize)
                                                * w
                                                + ix as usize]
                                                as i64;
                                    }
                                }
                            }
                            assert_eq!(
                                gw.data[((oi * c + ci) * 3 + ki) * 3 + kj],
                                acc
                            );
                        }
                    }
                }
            }
        });
    }

    #[test]
    fn matmul_pooled_tiled_bitexact_across_workers_prop() {
        // the persistent-pool + cache-blocked kernel must be bit-identical
        // to the naive reference for every worker budget, on both the
        // chunked-i32 fast path and the wide-operand i64 fallback
        prop::check("matmul_workers", 20, |g| {
            let m = g.usize_in(1, 33);
            let k = g.usize_in(1, 700); // > MM_KTILE exercises k-tiling
            let n = g.usize_in(1, 90); // > MM_JTILE exercises j-tiling
            let wide = g.usize_in(0, 3) == 0;
            // wide operands force safe_chunk -> None (single product past
            // the i32 rail) while keeping the i64 batch sum far from
            // overflow: 50k * 50k * 700 ≈ 1.8e12 << i64::MAX
            let (lo, hi) = if wide { (-50_000, 50_000) } else { (-127, 127) };
            let mut av = g.vec_i32(m * k, lo, hi);
            let mut bv = g.vec_i32(k * n, lo, hi);
            if wide {
                av[0] = 50_000; // pin the max so the product exceeds i32
                bv[0] = -50_000;
            }
            let a = ITensor::from_vec(&[m, k], av);
            let b = ITensor::from_vec(&[k, n], bv);
            let want = matmul_naive(&a, &b);
            for isa in supported_isas() {
                for workers in [1usize, 2, 3, 8] {
                    let mut out = vec![0i64; m * n];
                    matmul_i64_into(isa, &a.data, &b.data, m, k, n, &mut out,
                                    workers);
                    assert_eq!(out, want.data,
                               "isa={} workers={workers} wide={wide}",
                               isa.name());
                }
            }
        });
    }

    #[test]
    fn matmul_backend_bitexact_across_isas_prop() {
        // every supported ISA through the KernelBackend surface must
        // reproduce the naive reference, on both the chunked-i32 fast
        // path and (rail-pinned operands) the wide i64 fallback
        prop::check("matmul_isa", 15, |g| {
            let m = g.usize_in(1, 9);
            let k = g.usize_in(1, 80);
            let n = g.usize_in(1, 70); // > MM_JTILE exercises j-tiling
            let wide = g.usize_in(0, 2) == 0;
            let mut av = g.vec_i32(m * k, -127, 127);
            let mut bv = g.vec_i32(k * n, -127, 127);
            if wide {
                av[0] = i32::MAX; // single product past the i32 rail
                bv[0] = -i32::MAX;
            }
            let a = ITensor::from_vec(&[m, k], av);
            let b = ITensor::from_vec(&[k, n], bv);
            let want = matmul_naive(&a, &b);
            for isa in supported_isas() {
                let kb = KernelBackend::with_isa(isa);
                let mut out = vec![0i64; m * n];
                kb.matmul_i64(&a.data, &b.data, m, k, n, &mut out, 2);
                assert_eq!(out, want.data, "isa={} wide={wide}", isa.name());
            }
        });
    }

    #[test]
    fn im2col_row_copy_matches_scalar_reference_prop() {
        // the SIMD row-copy extraction must be byte-identical to the
        // scalar per-element loop across kernel/padding geometries,
        // including pads that clip patches on every edge
        prop::check("im2col_isa", 20, |g| {
            let b = g.usize_in(1, 2);
            let c = g.usize_in(1, 3);
            let k = [1usize, 3, 5][g.usize_in(0, 2)];
            let pad = g.usize_in(0, 2);
            let h = g.usize_in(k.max(2), 9);
            let w = g.usize_in(k.max(2), 9);
            let x = ITensor::from_vec(&[b, c, h, w],
                                      g.vec_i32(b * c * h * w, -127, 127));
            let want = im2col_isa(Isa::Scalar, &x, k, pad);
            for isa in supported_isas() {
                assert_eq!(im2col_isa(isa, &x, k, pad), want,
                           "isa={} k={k} pad={pad} h={h} w={w}", isa.name());
            }
        });
    }

    #[test]
    fn conv_backend_bitexact_across_isas() {
        let mut g = Pcg32::new(23);
        let x = rand_it(&mut g, &[2, 3, 7, 6], -127, 127);
        let wt = rand_it(&mut g, &[4, 3, 3, 3], -500, 500);
        let gr = rand_it(&mut g, &[2, 4, 7, 6], -20, 20);
        let sf = scale_factor_conv(3, 3);
        let mut want: Option<(LTensor, ITensor, LTensor)> = None;
        for isa in supported_isas() {
            let kb = KernelBackend::with_isa(isa);
            let mut ws = KernelWorkspace::new();
            let z = kb.conv2d(&x, &wt, 1, &mut ws);
            let mut s = ITensor::empty();
            kb.conv2d_scale(&x, &wt, 1, sf, &mut ws, &mut s);
            let gw = kb.conv2d_weight_grad(&x, &gr, 3, 1, &mut ws);
            match &want {
                None => want = Some((z, s, gw)),
                Some((wz, wss, wgw)) => {
                    assert_eq!(&z, wz, "conv2d isa={}", isa.name());
                    assert_eq!(&s, wss, "conv2d_scale isa={}", isa.name());
                    assert_eq!(&gw, wgw, "weight_grad isa={}", isa.name());
                }
            }
        }
    }

    #[test]
    fn fused_matmul_scale_ws_equals_composition_prop() {
        prop::check("matmul_scale_ws", 15, |g| {
            let mut ws = KernelWorkspace::new();
            // reuse one workspace across every case/shape in sequence
            for _ in 0..3 {
                let m = g.usize_in(1, 9);
                let k = g.usize_in(1, 40);
                let n = g.usize_in(1, 12);
                let a = ITensor::from_vec(&[m, k], g.vec_i32(m * k, -127, 127));
                let b =
                    ITensor::from_vec(&[k, n], g.vec_i32(k * n, -4000, 4000));
                let sf = scale_factor_linear(k);
                let fused = matmul_scale_ws(&a, &b, sf, &mut ws);
                let composed = nitro_scale(&matmul_i64(&a, &b), sf);
                assert_eq!(fused, composed);
            }
        });
    }

    #[test]
    fn conv_workspace_paths_bitexact_prop() {
        // conv2d_i64_ws / conv2d_scale_ws / conv2d_weight_grad_ws with a
        // single long-lived workspace (buffers growing and shrinking
        // across shapes) must match the plain kernels exactly
        prop::check("conv_ws", 10, |g| {
            let mut ws = KernelWorkspace::new();
            for _ in 0..3 {
                let b = g.usize_in(1, 3);
                let c = g.usize_in(1, 4);
                let o = g.usize_in(1, 5);
                let h = g.usize_in(3, 9);
                let w = g.usize_in(3, 9);
                let x = ITensor::from_vec(&[b, c, h, w],
                                          g.vec_i32(b * c * h * w, -127, 127));
                let wt = ITensor::from_vec(&[o, c, 3, 3],
                                           g.vec_i32(o * c * 9, -500, 500));
                let z_ws = conv2d_i64_kb(&x, &wt, 1, &mut ws);
                let z = conv2d_i64(&x, &wt, 1);
                assert_eq!(z_ws, z);
                let sf = scale_factor_conv(3, c);
                let fused = conv2d_scale_ws(&x, &wt, 1, sf, &mut ws);
                assert_eq!(fused, nitro_scale(&z, sf));
                let gr = ITensor::from_vec(&[b, o, h, w],
                                           g.vec_i32(b * o * h * w, -20, 20));
                // patches for x are now cached; the ws path must equal the
                // fresh extraction
                let gw_ws = conv2d_weight_grad_kb(&x, &gr, 3, 1, &mut ws);
                let gw = conv2d_weight_grad(&x, &gr, 3, 1);
                assert_eq!(gw_ws, gw);
            }
        });
    }

    #[test]
    fn forward_always_refreshes_patches_for_new_data() {
        // two same-shaped batches through one workspace (exactly what
        // consecutive training steps look like): the second forward must
        // re-extract, never reuse the first batch's patches — this is the
        // release-mode contract, where the tag carries no data fingerprint
        let mut g = Pcg32::new(11);
        let mut ws = KernelWorkspace::new();
        let wt = rand_it(&mut g, &[4, 3, 3, 3], -300, 300);
        let x1 = rand_it(&mut g, &[2, 3, 6, 6], -127, 127);
        let x2 = rand_it(&mut g, &[2, 3, 6, 6], -127, 127);
        assert_ne!(x1, x2);
        let _ = conv2d_i64_kb(&x1, &wt, 1, &mut ws);
        assert_eq!(conv2d_i64_kb(&x2, &wt, 1, &mut ws),
                   conv2d_i64(&x2, &wt, 1));
        let sf = scale_factor_conv(3, 3);
        assert_eq!(conv2d_scale_ws(&x2, &wt, 1, sf, &mut ws),
                   nitro_scale(&conv2d_i64(&x2, &wt, 1), sf));
        // and the weight grad then consumes x2's patches, not x1's
        let gr = rand_it(&mut g, &[2, 4, 6, 6], -20, 20);
        assert_eq!(conv2d_weight_grad_kb(&x2, &gr, 3, 1, &mut ws),
                   conv2d_weight_grad(&x2, &gr, 3, 1));
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "invalidate_patches")]
    fn stale_patch_reuse_is_trapped_in_debug() {
        // mutate the input between the fused forward and the weight
        // grad WITHOUT invalidate_patches: same geometry, different
        // bytes — debug builds must refuse to reuse the stale patches
        let mut g = Pcg32::new(41);
        let mut ws = KernelWorkspace::new();
        let mut x = rand_it(&mut g, &[1, 2, 5, 5], -127, 127);
        let wt = rand_it(&mut g, &[3, 2, 3, 3], -300, 300);
        let _ = conv2d_i64_kb(&x, &wt, 1, &mut ws);
        x.data[0] ^= 1; // caller mutates the input in place
        let gr = rand_it(&mut g, &[1, 3, 5, 5], -20, 20);
        let _ = conv2d_weight_grad_kb(&x, &gr, 3, 1, &mut ws);
    }

    #[test]
    fn invalidate_patches_makes_mutated_input_safe() {
        // the documented fix for the trap above: invalidate, then the
        // weight grad re-extracts and matches a fresh computation
        let mut g = Pcg32::new(42);
        let mut ws = KernelWorkspace::new();
        let mut x = rand_it(&mut g, &[1, 2, 5, 5], -127, 127);
        let wt = rand_it(&mut g, &[3, 2, 3, 3], -300, 300);
        let _ = conv2d_i64_kb(&x, &wt, 1, &mut ws);
        x.data[0] ^= 1;
        ws.invalidate_patches();
        let gr = rand_it(&mut g, &[1, 3, 5, 5], -20, 20);
        assert_eq!(conv2d_weight_grad_kb(&x, &gr, 3, 1, &mut ws),
                   conv2d_weight_grad(&x, &gr, 3, 1));
    }

    #[test]
    fn weight_grad_patch_cache_invalidation() {
        let mut g = Pcg32::new(7);
        let mut ws = KernelWorkspace::new();
        let x1 = rand_it(&mut g, &[2, 3, 5, 5], -127, 127);
        let wt = rand_it(&mut g, &[4, 3, 3, 3], -300, 300);
        let _ = conv2d_i64_kb(&x1, &wt, 1, &mut ws);
        // a conv over a *different shape* must not reuse x1's patches
        let x2 = rand_it(&mut g, &[2, 3, 6, 6], -127, 127);
        let gr2 = rand_it(&mut g, &[2, 4, 6, 6], -20, 20);
        assert_eq!(
            conv2d_weight_grad_kb(&x2, &gr2, 3, 1, &mut ws),
            conv2d_weight_grad(&x2, &gr2, 3, 1)
        );
        // explicit invalidation forces re-extraction, result unchanged
        ws.invalidate_patches();
        let gr1 = rand_it(&mut g, &[2, 4, 5, 5], -20, 20);
        assert_eq!(
            conv2d_weight_grad_kb(&x1, &gr1, 3, 1, &mut ws),
            conv2d_weight_grad(&x1, &gr1, 3, 1)
        );
    }

    #[test]
    fn into_variants_match_owning_kernels_with_reused_buffers() {
        // the serving forward path's caller-buffer kernels must be
        // bit-identical to the owning forms across shapes, with one set of
        // long-lived buffers growing/shrinking between calls
        prop::check("into_kernels", 12, |g| {
            let mut ws = KernelWorkspace::new();
            let mut out = ITensor::empty();
            for _ in 0..3 {
                let m = g.usize_in(1, 9);
                let k = g.usize_in(1, 40);
                let n = g.usize_in(1, 12);
                let a = ITensor::from_vec(&[m, k], g.vec_i32(m * k, -127, 127));
                let b =
                    ITensor::from_vec(&[k, n], g.vec_i32(k * n, -4000, 4000));
                let sf = scale_factor_linear(k);
                kernels().matmul_scale(&a, &b, sf, &mut ws, &mut out);
                assert_eq!(out, nitro_scale(&matmul_i64(&a, &b), sf));

                let bt = g.usize_in(1, 3);
                let c = g.usize_in(1, 3);
                let o = g.usize_in(1, 4);
                let h = g.usize_in(4, 9);
                let x = ITensor::from_vec(&[bt, c, h, h],
                                          g.vec_i32(bt * c * h * h, -127, 127));
                let wt = ITensor::from_vec(&[o, c, 3, 3],
                                           g.vec_i32(o * c * 9, -500, 500));
                let csf = scale_factor_conv(3, c);
                kernels().conv2d_scale(&x, &wt, 1, csf, &mut ws, &mut out);
                assert_eq!(out, nitro_scale(&conv2d_i64(&x, &wt, 1), csf));

                let (pooled, _) = maxpool2d(&x, 2, 2);
                kernels().maxpool2d(&x, 2, 2, &mut out);
                assert_eq!(out, pooled);

                let mut zs =
                    ITensor::from_vec(&[bt, c * h * h],
                                      g.vec_i32(bt * c * h * h, -300, 300));
                let want = nitro_relu(&zs, 10);
                nitro_relu_inplace(&mut zs, 10);
                assert_eq!(zs, want);
            }
        });
    }

    #[test]
    fn maxpool_first_max_wins_and_bwd_routes() {
        // tie in a window: first (row-major) index must win
        let x = ITensor::from_vec(&[1, 1, 2, 2], vec![5, 5, 5, 5]);
        let (p, a) = maxpool2d(&x, 2, 2);
        assert_eq!(p.data, vec![5]);
        assert_eq!(a.data, vec![0]);
        let g = ITensor::from_vec(&[1, 1, 1, 1], vec![7]);
        let gx = maxpool2d_bwd(&g, &a, &[1, 1, 2, 2], 2, 2);
        assert_eq!(gx.data, vec![7, 0, 0, 0]);
    }

    #[test]
    fn maxpool_gradient_conserved_prop() {
        prop::check("pool", 20, |g| {
            let (b, c) = (g.usize_in(1, 2), g.usize_in(1, 3));
            let h = g.usize_in(2, 8) & !1; // even
            let h = h.max(2);
            let x = rand_it(&mut g.rng, &[b, c, h, h], -127, 127);
            let (p, a) = maxpool2d(&x, 2, 2);
            let gr = rand_it(&mut g.rng, &p.shape, -50, 50);
            let gx = maxpool2d_bwd(&gr, &a, &x.shape, 2, 2);
            let sum_in: i64 = gr.data.iter().map(|&v| v as i64).sum();
            let sum_out: i64 = gx.data.iter().map(|&v| v as i64).sum();
            assert_eq!(sum_in, sum_out);
        });
    }

    #[test]
    fn scale_factors_checked_clamped_and_erroring() {
        // normal cases unchanged
        assert_eq!(scale_factor_linear(784), 256 * 784);
        assert_eq!(scale_factor_conv(3, 64), 256 * 9 * 64);
        // degenerate fan-in clamps to >= 1 instead of a zero divisor
        assert_eq!(scale_factor_linear(0), 1);
        assert_eq!(scale_factor_conv(0, 64), 1);
        assert_eq!(scale_factor_conv(3, 0), 1);
        // overflow is a typed error, never a wrapped factor
        assert!(try_scale_factor_linear(usize::MAX).is_err());
        assert!(try_scale_factor_linear((i64::MAX / 200) as usize).is_err());
        assert!(try_scale_factor_conv(usize::MAX, 2).is_err());
        assert!(try_scale_factor_conv(1 << 31, 1 << 31).is_err());
        // largest representable factor still succeeds
        let big = (i64::MAX / 256) as usize;
        assert_eq!(try_scale_factor_linear(big), Ok(256 * big as i64));
    }

    #[test]
    #[should_panic(expected = "scale factor overflow")]
    fn scale_factor_overflow_panics_with_typed_message() {
        let _ = scale_factor_linear(usize::MAX);
    }

    #[test]
    fn pow2_shift_detects_exact_powers_only() {
        assert_eq!(pow2_shift(1), Some(0));
        assert_eq!(pow2_shift(256), Some(8));
        assert_eq!(pow2_shift(1 << 62), Some(62));
        for bad in [0i64, -1, -256, 3, 255, 257, 256 * 784, i64::MAX] {
            assert_eq!(pow2_shift(bad), None, "{bad}");
        }
        // every real pow2 sf through nitro_scale stays floor-exact
        let z = LTensor::from_vec(&[1, 6], vec![-1, -255, -256, -257, 255, 256]);
        let s = nitro_scale(&z, 256);
        assert_eq!(s.data, vec![-1, -1, -1, -2, 0, 1]);
    }

    #[test]
    fn nitro_scale_floor_semantics() {
        let z = LTensor::from_vec(&[1, 6], vec![-1, -255, -256, -257, 255, 256]);
        let s = nitro_scale(&z, 256);
        assert_eq!(s.data, vec![-1, -1, -1, -2, 0, 1]);
    }

    #[test]
    fn nitro_relu_mu_pinned() {
        assert_eq!(nitro_relu_mu(10), (-13 + -7 + 63 + 127) / 4);
        assert_eq!(nitro_relu_mu(2), (-64 + -32 + 63 + 127) / 4);
    }

    #[test]
    fn fused_scale_relu_equals_composition_prop() {
        prop::check("fused", 25, |g| {
            let n = g.usize_in(1, 200);
            // in-contract pre-activations: the scaling-layer analysis
            // guarantees |z| <= SF * 2^7-ish; give it head-room up to
            // 2^38 so z/sf always fits the i32 the unfused path stores
            let z = LTensor::from_vec(
                &[1, n],
                g.vec_i64(n)
                    .into_iter()
                    .map(|v| v.clamp(-(1 << 38), 1 << 38))
                    .collect(),
            );
            for &(sf, ai) in &[(256i64, 10i64), (256 * 9 * 64, 2), (256 * 784, 100)] {
                let a = nitro_relu(&nitro_scale(&z, sf), ai);
                let b = nitro_scale_relu(&z, sf, ai);
                assert_eq!(a, b);
            }
        });
    }

    #[test]
    fn relu_bwd_segments() {
        let zs = ITensor::from_vec(&[1, 5], vec![-200, -100, -1, 50, 200]);
        let g = ITensor::from_vec(&[1, 5], vec![1000, 1000, -1000, 7, 7]);
        let gz = nitro_relu_bwd(&zs, &g, 10);
        assert_eq!(gz.data, vec![0, 100, -100, 7, 0]);
    }

    #[test]
    fn one_hot_and_rss() {
        let y = one_hot32(&[1, 0], 3);
        assert_eq!(y.data, vec![0, 32, 0, 32, 0, 0]);
        let yhat = ITensor::from_vec(&[2, 3], vec![0, 30, 0, 10, 0, 0]);
        let (loss, grad) = rss_loss_grad(&yhat, &y);
        assert_eq!(loss, (4 + 484) / 2);
        assert_eq!(grad.data, vec![0, -2, 0, -22, 0, 0]);
    }
}
